#!/usr/bin/env python3
"""Benchmark regression gate for the serving benchmark JSON artifact.

Compares a fresh ``benchmarks.serving --json`` result against a committed
baseline and fails (exit 1) on a regression beyond tolerance in any gated
metric:

- ``rates.<rate>.continuous.tok_s``      (throughput: lower is a regression)
- ``shared_prefix.{off,on}.tok_s``
- ``shared_prefix.{off,on}.ttft_ms``     (mean TTFT: higher is a regression)
- ``sampled.{greedy,sampled,sampled_ref}.tok_s``
- ``sampled.sampler_overhead_pct``       (fused sampler tax over greedy, in
                                          percentage points: current may
                                          exceed baseline by at most
                                          100 * tolerance points — a
                                          relative gate on a near-zero
                                          percentage would flap on noise)
- ``sampled.diverged_streams``           (fused vs reference filter token
                                          mismatches: must be exactly 0 —
                                          divergence is a determinism bug,
                                          not a perf number)
- ``families.<arch>.tok_s``              (hybrid/SSM/MoE serving sweep)
- ``multistep.n<N>.tok_s``               (multi-step compiled decode loop at
                                          decode_steps N in {1,4,16})
- ``multistep.n<N>.dispatches_per_token`` (host dispatches per decode token:
                                          higher is a regression; the bench
                                          itself also hard-bounds it at
                                          1.1/N)
- ``multistep.n<N>.speedup_vs_n1``       (N>1 throughput over the N=1 run in
                                          the SAME artifact: gated so the
                                          loop never ships slower than
                                          single-step)
- ``multistep.diverged_streams``         (N>1 vs N=1 token mismatches: must
                                          be exactly 0 — determinism bug,
                                          not a perf number)
- ``decode_fusion.{unfused,fused,fused_n4}.tok_s``
                                         (decode residual-stream fusion)
- ``decode_fusion.speedup_vs_unfused``   (fused over unfused throughput in
                                          the SAME artifact: a noise floor —
                                          on CPU the fused graph is
                                          op-identical, so ~0.8-1.0x is
                                          healthy and only a real cliff
                                          fails)
- ``decode_fusion.diverged_streams``     (fused vs unfused token mismatches:
                                          must be exactly 0 — the fusion's
                                          whole contract is bit-identical
                                          streams)
- ``recompiles.excess``                  (jit cache misses after warmup:
                                          must be exactly 0 — a retrace is
                                          a correctness bug, not a perf
                                          number, so tolerance never applies)

Every metric present in the *baseline* must exist in the current result —
a silently missing section (a partial artifact) fails the gate too. Extra
sections in the current result (e.g. ``tensor_parallel``) are ignored, so
the baseline does not usually need regenerating when new sections land —
EXCEPT the sections in ``REQUIRED_SECTIONS``, which the baseline itself
must carry: a baseline that predates them silently un-gates that coverage,
so the gate fails until it is regenerated.

Usage:
    python tools/check_bench.py serving_bench.json \
        benchmarks/baselines/serving.json [--tolerance 0.2]

Re-baselining (numbers are machine-class specific — regenerate on the CI
runner class, not a laptop): download ``serving_bench.json`` from a green CI
run's artifacts and commit it as ``benchmarks/baselines/serving.json``, or
locally:

    PYTHONPATH=src python -m benchmarks.serving --requests 8 \
        --json benchmarks/baselines/serving.json

The tolerance is deliberately loose (default 20%, override with
``--tolerance`` or the ``CHECK_BENCH_TOLERANCE`` env var): the gate exists
to catch order-of-magnitude perf cliffs (a decode path falling off its
compiled fast path, prefix caching silently disabled), not scheduler noise.
No external dependencies — stdlib only, importable for unit tests.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, Iterator, List, Optional, Tuple

# (metric path, value, direction); direction "higher" = bigger is better,
# "lower" = smaller is better, "zero" = must be exactly 0 (no tolerance),
# "lower_points" = a percentage gated in absolute points
# (cur <= base + 100 * tolerance)
Metric = Tuple[str, float, str]

# sections the BASELINE must carry: absence means it predates the coverage
# (and would silently un-gate it) — regenerate and commit a fresh artifact
REQUIRED_SECTIONS = ("families", "recompiles", "sampled", "multistep",
                     "decode_fusion")


def iter_metrics(baseline: dict) -> Iterator[Metric]:
    """Yield every gated metric the baseline carries."""
    for rate, d in baseline.get("rates", {}).items():
        if "continuous" in d:
            yield (f"rates.{rate}.continuous.tok_s",
                   d["continuous"]["tok_s"], "higher")
    for tag in ("off", "on"):
        d = baseline.get("shared_prefix", {}).get(tag)
        if d:
            yield f"shared_prefix.{tag}.tok_s", d["tok_s"], "higher"
            yield f"shared_prefix.{tag}.ttft_ms", d["ttft_ms"], "lower"
    for tag in ("greedy", "sampled", "sampled_ref"):
        d = baseline.get("sampled", {}).get(tag)
        if d:
            yield f"sampled.{tag}.tok_s", d["tok_s"], "higher"
    sampled = baseline.get("sampled", {})
    if "sampler_overhead_pct" in sampled:
        yield ("sampled.sampler_overhead_pct",
               sampled["sampler_overhead_pct"], "lower_points")
    if "diverged_streams" in sampled:
        yield ("sampled.diverged_streams",
               sampled["diverged_streams"], "zero")
    for arch, d in baseline.get("families", {}).items():
        if "tok_s" in d:
            yield f"families.{arch}.tok_s", d["tok_s"], "higher"
    multistep = baseline.get("multistep", {})
    for tag in ("n1", "n4", "n16"):
        d = multistep.get(tag)
        if d:
            yield f"multistep.{tag}.tok_s", d["tok_s"], "higher"
            if "dispatches_per_token" in d:
                yield (f"multistep.{tag}.dispatches_per_token",
                       d["dispatches_per_token"], "lower")
            if "speedup_vs_n1" in d:
                yield (f"multistep.{tag}.speedup_vs_n1",
                       d["speedup_vs_n1"], "higher")
    if "diverged_streams" in multistep:
        yield ("multistep.diverged_streams",
               multistep["diverged_streams"], "zero")
    fusion = baseline.get("decode_fusion", {})
    for tag in ("unfused", "fused", "fused_n4"):
        d = fusion.get(tag)
        if d and "tok_s" in d:
            yield f"decode_fusion.{tag}.tok_s", d["tok_s"], "higher"
    if "speedup_vs_unfused" in fusion:
        yield ("decode_fusion.speedup_vs_unfused",
               fusion["speedup_vs_unfused"], "higher")
    if "diverged_streams" in fusion:
        yield ("decode_fusion.diverged_streams",
               fusion["diverged_streams"], "zero")
    if "recompiles" in baseline:
        yield ("recompiles.excess",
               baseline["recompiles"].get("excess", 0), "zero")


def lookup(result: dict, path: str) -> Optional[float]:
    """Resolve a dotted metric path. Keys may themselves contain dots (arch
    names like ``mamba2-1.3b``), so at each level the longest join of
    remaining segments that is an actual key wins."""
    node = result
    parts = path.split(".")
    i = 0
    while i < len(parts):
        if not isinstance(node, dict):
            return None
        for j in range(len(parts), i, -1):
            key = ".".join(parts[i:j])
            if key in node:
                node = node[key]
                i = j
                break
        else:
            return None
    return float(node) if isinstance(node, (int, float)) else None


def compare(current: dict, baseline: dict,
            tolerance: float) -> List[Dict[str, object]]:
    """-> one row per gated metric: {metric, baseline, current, ok, note}."""
    rows: List[Dict[str, object]] = []
    for sec in REQUIRED_SECTIONS:
        if baseline and sec not in baseline:
            rows.append({"metric": f"{sec}.<section>", "baseline": None,
                         "current": None, "ok": False,
                         "note": "REQUIRED section absent from baseline — "
                                 "re-baseline (see docstring)"})
    for path, base, direction in iter_metrics(baseline):
        cur = lookup(current, path)
        if cur is None:
            rows.append({"metric": path, "baseline": base, "current": None,
                         "ok": False, "note": "MISSING from current result"})
            continue
        if direction == "zero":
            ok = cur == 0
            note = "zero, as required" if ok else \
                f"{cur:g} != 0 — a correctness invariant broke, not a " \
                "perf number"
        elif direction == "lower_points":
            # percentage metric, gated in absolute points: a relative bound
            # on a near-zero base would reject harmless noise
            ok = cur <= base + 100.0 * tolerance
            note = f"{cur - base:+.1f}pp"
        elif direction == "higher":
            ok = cur >= base * (1.0 - tolerance)
            note = f"{(cur - base) / base:+.1%}" if base else "+0.0%"
        else:
            ok = cur <= base * (1.0 + tolerance)
            note = f"{(cur - base) / base:+.1%}" if base else "+0.0%"
        rows.append({"metric": path, "baseline": base, "current": cur,
                     "ok": ok, "note": note})
    if not rows:
        rows.append({"metric": "<none>", "baseline": None, "current": None,
                     "ok": False, "note": "baseline carries no gated metrics"})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="fresh benchmarks.serving --json output")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("CHECK_BENCH_TOLERANCE",
                                                 0.2)),
                    help="allowed fractional regression (default 0.2)")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    rows = compare(current, baseline, args.tolerance)
    width = max(len(r["metric"]) for r in rows)
    failed = [r for r in rows if not r["ok"]]
    for r in rows:
        status = "ok  " if r["ok"] else "FAIL"
        base = "-" if r["baseline"] is None else f"{r['baseline']:.2f}"
        cur = "-" if r["current"] is None else f"{r['current']:.2f}"
        print(f"[check_bench] {status} {r['metric']:<{width}} "
              f"base={base} cur={cur} ({r['note']})")
    if failed:
        print(f"[check_bench] {len(failed)}/{len(rows)} metrics regressed "
              f"beyond {args.tolerance:.0%} — see docstring for how to "
              "re-baseline after an intentional change", file=sys.stderr)
        return 1
    print(f"[check_bench] all {len(rows)} metrics within "
          f"{args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
