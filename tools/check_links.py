#!/usr/bin/env python
"""Markdown link check for README.md and docs/ (CI: docs must not rot).

Checks, for every ``[text](target)`` in the given files/directories:

- relative file targets resolve on disk (anchors stripped first);
- in-page ``#anchor`` targets match a heading's GitHub-style slug;
- external ``http(s)://``/``mailto:`` targets are syntax-checked only — no
  network, so the job is deterministic and offline-safe.

    python tools/check_links.py README.md docs

Exits non-zero listing every broken link.
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK = re.compile(r"\[[^\]\[]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE = re.compile(r"```.*?```", re.DOTALL)


def slugify(heading: str) -> str:
    """GitHub's anchor slug: lowercase, punctuation dropped, spaces to
    dashes (inline code/emphasis markers stripped first)."""
    text = re.sub(r"[`*_]", "", heading.strip()).lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _slugs(markdown: str) -> set[str]:
    """Anchor slugs of a document's real headings — fenced code is stripped
    first so a '# comment' inside a code block can't satisfy an anchor."""
    return {slugify(h) for h in HEADING.findall(FENCE.sub("", markdown))}


def check_file(md: pathlib.Path) -> list[str]:
    raw = md.read_text(encoding="utf-8")
    text = FENCE.sub("", raw)                  # links inside code are literal
    slugs = _slugs(raw)
    errors = []
    for target in LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            if " " in target:
                errors.append(f"{md}: malformed URL {target!r}")
            continue
        if target.startswith("#"):
            if target[1:] not in slugs:
                errors.append(f"{md}: missing anchor {target!r}")
            continue
        path_part, _, anchor = target.partition("#")
        dest = (md.parent / path_part).resolve()
        if not dest.exists():
            errors.append(f"{md}: broken link {target!r} -> {dest}")
        elif anchor and dest.suffix == ".md":
            if anchor not in _slugs(dest.read_text(encoding="utf-8")):
                errors.append(f"{md}: missing anchor {target!r} in {dest}")
    return errors


def main(argv: list[str]) -> int:
    files: list[pathlib.Path] = []
    for arg in argv or ["README.md", "docs"]:
        p = pathlib.Path(arg)
        files.extend(sorted(p.rglob("*.md")) if p.is_dir() else [p])
    errors = []
    for md in files:
        errors.extend(check_file(md))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} file(s): "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
