#!/usr/bin/env python3
"""jaxlint CLI — JAX/Pallas-aware static analysis for this repo.

Usage:
    python tools/jaxlint.py src benchmarks tools
    python tools/jaxlint.py --list-rules

Thin launcher: the implementation lives in ``src/repro/analysis/lint.py``
and is loaded *by file path* so the lint CI job needs neither a PYTHONPATH
nor a jax install — ``repro`` is a namespace package and ``lint`` is
stdlib-only by design. Exit 0 = clean, 1 = findings (printed as
``path:line:col: [rule] message``).
"""
import importlib.util
import sys
from pathlib import Path

_LINT = Path(__file__).resolve().parents[1] / "src" / "repro" / "analysis" \
    / "lint.py"


def _load():
    spec = importlib.util.spec_from_file_location("_jaxlint_impl", _LINT)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod        # dataclasses resolve via sys.modules
    spec.loader.exec_module(mod)
    return mod


if __name__ == "__main__":
    sys.exit(_load().main(sys.argv[1:]))
