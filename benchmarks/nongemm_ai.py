"""Paper Fig 8: arithmetic intensity + bandwidth demands of non-GEMM phases."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import analytical

from .common import emit


def run() -> None:
    bert = get_config("bert-large")
    ops = analytical.nongemm_ops(bert, 32, 128, dtype_bytes=4)
    max_bw_op = max(ops, key=lambda e: e.total_bytes)
    for e in ops:
        emit(f"fig8/{e.name}", 0.0,
             f"ops_per_byte={e.intensity:.2f};"
             f"rel_bw={e.total_bytes/max_bw_op.total_bytes:.2f};"
             f"kernels={e.count}")
    # Takeaway 8: LAMB stage 1 READS w,g,m,v = 4x model size (writes extra)
    model_bytes = bert.param_count() * 4
    lamb_reads = 4 * model_bytes
    lamb_total = sum(e.total_bytes for e in ops if e.layer == "lamb")
    emit("fig8/lamb_traffic_vs_model", 0.0,
         f"read_ratio={lamb_reads/model_bytes:.1f};"
         f"total_rw_ratio={lamb_total/model_bytes:.1f};paper_claim=4x reads")
