"""Paper Fig 14/15: fusing the attention QKV linear GEMMs into one.

Measured CPU wall-clock of 3 serial [T,d]x[d,d] GEMMs vs one [T,d]x[d,3d],
across token counts (the paper: up to 62% faster, more at small inputs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import emit, time_fn


def run() -> None:
    d = 1024
    for t in (512, 2048, 8192):
        x = jax.random.normal(jax.random.key(0), (t, d), jnp.float32)
        wq, wk, wv = (jax.random.normal(jax.random.key(i), (d, d),
                                        jnp.float32) * 0.02
                      for i in (1, 2, 3))
        wf = jnp.concatenate([wq, wk, wv], axis=1)

        serial = jax.jit(lambda xx: (xx @ wq, xx @ wk, xx @ wv))
        fused = jax.jit(lambda xx: jnp.split(xx @ wf, 3, axis=1))

        t_s = time_fn(serial, x)
        t_f = time_fn(fused, x)
        emit(f"fig15/T{t}_serial", t_s, "gemms=3")
        emit(f"fig15/T{t}_fused", t_f,
             f"gemms=1;speedup={t_s/t_f:.2f}")
