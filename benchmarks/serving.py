"""Serving throughput/latency: continuous batching vs the static engine, and
prefix caching on a shared-system-prompt trace.

A Poisson arrival trace of requests with heterogeneous generation lengths is
served by both engines at several request rates. The static engine groups
arrivals into fixed batches and decodes each batch in lock-step until its
*longest* member finishes — short requests burn decode steps producing tokens
nobody asked for. The continuous engine recycles a finished slot into the
next queued request immediately, so aggregate tokens/sec tracks useful work.

The second section is the paper's memory-bound serving story end to end: a
trace whose requests share one long system prompt (the production shape —
millions of users, one template) is served with the prefix cache off and on.
With it on, the shared prompt's K/V pages are computed once and refcounted
into every request's page table, so prefill tokens computed, time-to-first-
token, and peak pages-in-use all drop.

The third section prices stochastic decoding: the same trace served greedy,
with per-request temperature/top-k/top-p (chat-shaped traffic) through the
fused sort-free sampler, and once more through the sort-based reference
filter — so the sampler's overhead shows up as a tok/s delta instead of a
guess, the fused kernel's win over the twin-sort epilogue is priced in the
same table, and any fused-vs-reference token divergence
(``diverged_streams``, pinned at 0 by the determinism contract) fails the
``check_bench`` gate.

The ``families`` section serves the non-dense architectures the decode-state
protocol opened up — pure-SSM mamba2, hybrid jamba, and token-choice
deepseek-moe smoke configs — through the same continuous engine, recording
tok/s, latency, and the per-family prefix-cache gate (forced off, with the
recorded reason, for SSM-bearing archs). ``tools/check_bench.py`` requires
this section in the baseline.

The ``multistep`` section prices the multi-step compiled decode loop: the
same mixed greedy/sampled trace served at ``decode_steps`` N in {1, 4, 16}.
It records tok/s, host dispatches per decode token (hard-bounded in-bench at
``< 1.1/N`` — a deterministic count), the host-sync reduction factor, and
``diverged_streams`` vs N=1 (the determinism contract pins it at 0).
``tools/check_bench.py`` requires this section too.

The ``decode_fusion`` section prices the fused decode residual stream +
streaming LM-head epilogue: the same mixed trace served with
``fused_decode`` off and on (plus fused at decode_steps=4), recording the
fused/unfused throughput ratio, ``diverged_streams`` (the bit-parity
contract pins fused-vs-unfused mismatches at exactly 0), and the analytic
per-decode-token HBM bytes the fusion removes on an accelerator (the f32
``[1, V]`` logits round-trip plus one hidden-width round-trip per fused
residual+norm site). Required by ``tools/check_bench.py`` as well.

With ``--tp N`` (N > 1; needs N devices — on CPU set
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) a fourth section
serves the same trace through the tensor-parallel engine: tok/s vs tp=1, the
number of diverged token streams (0 expected), per-device pages-in-use /
KV bytes under head sharding, and the analytic all-reduce wire bytes.

    PYTHONPATH=src python -m benchmarks.serving [--arch llama3.2-3b] \
        [--json serving_bench.json] [--tp 2]

Emits ``name,us_per_call,derived`` CSV rows like the other benchmarks, plus a
human-readable summary with p50/p99 inter-token latency; ``--json`` writes
the full result dict (CI uploads it as an artifact, and
``tools/check_bench.py`` gates it against ``benchmarks/baselines/``).
An engine error — any request finishing with an ``"error"`` result the trace
did not ask for, or an engine exception — exits nonzero WITHOUT writing the
JSON artifact, so CI never uploads (or gates on) a partial result as if it
were healthy.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import (ContinuousEngine, Request, SamplingParams,
                           pages_needed)

from .common import emit

PAGE_SIZE = 16

# trace_stats() of every continuous engine the current run() built, so the
# summary can assert the jit caches stayed closed across ALL sections —
# a retrace anywhere in the bench shows up as nonzero ``recompiles.excess``
# and tools/check_bench.py gates on it
_ENGINE_STATS: list = []


class EngineError(RuntimeError):
    """A serving run produced error results the trace did not ask for."""


def chat_sampling(uid: int) -> SamplingParams:
    """The canonical chat-shaped sampling settings every stochastic section
    uses (seed = uid so streams are reproducible AND distinct per request);
    one definition, so the sampled and tp sections price the same traffic."""
    return SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=uid)


def make_trace(n_requests, rate, *, prompt_len=32, gen_range=(8, 64), seed=0):
    """Poisson arrivals (exponential inter-arrival at ``rate`` req/s) with
    ragged generation lengths."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests)) \
        if np.isfinite(rate) else np.zeros(n_requests)
    prompts = rng.integers(5, 500, (n_requests, prompt_len))
    gens = rng.integers(gen_range[0], gen_range[1] + 1, n_requests)
    return [Request(uid=i, prompt=[int(t) for t in prompts[i]],
                    max_new_tokens=int(gens[i]), arrival=float(arrivals[i]))
            for i in range(n_requests)]


def make_shared_prefix_trace(n_requests, *, system_len=50, user_range=(4, 12),
                             gen_range=(8, 24), seed=0):
    """Every request = one shared system prompt + a short unique user suffix
    (the template-serving shape prefix caching exists for). The default
    system_len is deliberately NOT page-aligned, so the shared tail page
    exercises the copy-on-write path too."""
    rng = np.random.default_rng(seed)
    system = [int(t) for t in rng.integers(5, 500, system_len)]
    reqs = []
    for i in range(n_requests):
        user = [int(t) for t in
                rng.integers(5, 500, int(rng.integers(*user_range)))]
        reqs.append(Request(uid=i, prompt=system + user,
                            max_new_tokens=int(rng.integers(gen_range[0],
                                                            gen_range[1] + 1))))
    return reqs


def run_static(model, params, requests, batch_size):
    """Fixed-batch baseline: arrivals grouped into batches of ``batch_size``;
    each batch waits for its last arrival, prefills together, and decodes
    until its longest generation finishes."""
    arch = model.arch
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    t0 = time.perf_counter()
    token_times = {r.uid: [] for r in requests}
    for start in range(0, len(requests), batch_size):
        group = requests[start:start + batch_size]
        b = len(group)
        plen = len(group[0].prompt)
        max_gen = max(r.max_new_tokens for r in group)
        # the batch cannot start before its last member arrives
        while time.perf_counter() - t0 < max(r.arrival for r in group):
            time.sleep(1e-4)
        caches = model.init_caches(None, b, plen + max_gen)
        tokens_np = np.asarray([r.prompt for r in group], np.int32)
        logits, caches = prefill(params, caches,
                                 {"tokens": jnp.asarray(tokens_np)})
        toks = jnp.argmax(logits[:, -1], axis=-1)
        for i, r in enumerate(group):
            token_times[r.uid].append(time.perf_counter() - t0)
        for step in range(max_gen - 1):
            db = {"tokens": toks[:, None],
                  "positions": jnp.full((b,), plen + step, jnp.int32)}
            logits, caches = decode(params, caches, db)
            toks = jnp.argmax(logits[:, -1], axis=-1)
            # jaxlint: allow[hot-host-sync] intentional: per-token latency
            # timestamps are the point of this benchmark loop
            toks.block_until_ready()
            now = time.perf_counter() - t0
            for i, r in enumerate(group):
                if step + 1 < r.max_new_tokens:   # useful token, not waste
                    token_times[r.uid].append(now)
    wall = time.perf_counter() - t0
    return token_times, wall


def run_continuous(model, params, requests, slots, *, prefix_cache=False,
                   tp=1, fused_sampling=None, warmup=None, decode_steps=1,
                   spare_pages=0, fused_decode=None):
    """Serve ``requests`` through one ContinuousEngine sized for the trace.
    Returns (uid -> token_times, full results dict, wall seconds, engine) —
    every section (rates / shared-prefix / sampled / tp) goes through here
    so the pool-sizing math lives in exactly one place. Error results are an
    engine failure (these traces all fit the pool): raise instead of letting
    the bench summarize a partial run as healthy.

    ``warmup`` (a list of Requests) is served through the same engine
    BEFORE the timer starts: sections that price a *delta* between engine
    configurations (sampled vs greedy) pass a warmup trace hitting every
    jit variant the timed trace needs, so the delta compares steady-state
    serving instead of being dominated by one-time trace + XLA-compile
    cost on a short trace."""
    max_seq = max(len(r.prompt) + r.max_new_tokens for r in requests)
    num_pages = slots * pages_needed(max_seq + 1, PAGE_SIZE) + 2 + spare_pages
    engine = ContinuousEngine(model, params, num_slots=slots,
                              num_pages=num_pages, page_size=PAGE_SIZE,
                              max_seq_len=max_seq + PAGE_SIZE,
                              prefix_cache=prefix_cache, tp=tp,
                              fused_sampling=fused_sampling,
                              decode_steps=decode_steps,
                              fused_decode=fused_decode)
    if warmup:
        wres = engine.run(list(warmup))
        werrors = {uid: r["error"] for uid, r in wres.items()
                   if "error" in r}
        if werrors:
            raise EngineError(f"warmup returned error results: {werrors}")
    t0 = time.perf_counter()
    results = engine.run(requests)
    wall = time.perf_counter() - t0
    errors = {uid: r["error"] for uid, r in results.items() if "error" in r}
    if errors:
        raise EngineError(f"engine returned error results: {errors}")
    times = {uid: r["token_times"] for uid, r in results.items()}
    _ENGINE_STATS.append(engine.trace_stats())
    return times, results, wall, engine


def summarize(token_times, wall):
    all_tokens = sum(len(v) for v in token_times.values())
    gaps = []
    for times in token_times.values():
        gaps.extend(np.diff(times))
    gaps = np.asarray(gaps) if gaps else np.zeros(1)
    return {"tok_s": all_tokens / wall,
            "p50_ms": float(np.percentile(gaps, 50) * 1e3),
            "p99_ms": float(np.percentile(gaps, 99) * 1e3)}


def mean_ttft_ms(token_times, requests):
    arrivals = {r.uid: r.arrival for r in requests}
    ttfts = [times[0] - arrivals[uid]
             for uid, times in token_times.items() if times]
    return float(np.mean(ttfts) * 1e3) if ttfts else float("nan")


def run_rates(model, params, n_requests, slots, rates, results):
    for rate in rates:
        trace = make_trace(n_requests, rate)
        tag = "inf" if np.isinf(rate) else f"{rate:g}"
        st_times, st_wall = run_static(model, params, trace, slots)
        st = summarize(st_times, st_wall)
        ct_times, _, ct_wall, _ = run_continuous(model, params, trace, slots)
        ct = summarize(ct_times, ct_wall)
        emit(f"serve_static_rate{tag}", st_wall * 1e6 / max(1, n_requests),
             f"{st['tok_s']:.1f}tok/s_p50={st['p50_ms']:.1f}ms_"
             f"p99={st['p99_ms']:.1f}ms")
        emit(f"serve_continuous_rate{tag}", ct_wall * 1e6 / max(1, n_requests),
             f"{ct['tok_s']:.1f}tok/s_p50={ct['p50_ms']:.1f}ms_"
             f"p99={ct['p99_ms']:.1f}ms")
        speedup = ct["tok_s"] / max(st["tok_s"], 1e-9)
        print(f"[serving] rate={tag} req/s: static {st['tok_s']:.1f} tok/s "
              f"vs continuous {ct['tok_s']:.1f} tok/s "
              f"({speedup:.2f}x aggregate throughput)")
        results["rates"][tag] = {"static": st, "continuous": ct,
                                 "speedup": speedup}


def run_shared_prefix(model, params, n_requests, slots, results):
    trace = make_shared_prefix_trace(n_requests)
    out = {}
    for prefix_cache in (False, True):
        times, _, wall, engine = run_continuous(model, params, trace, slots,
                                                prefix_cache=prefix_cache)
        tag = "on" if prefix_cache else "off"
        out[tag] = {
            **summarize(times, wall),
            "ttft_ms": mean_ttft_ms(times, trace),
            "prefill_tokens": engine.prefill_tokens,
            "cached_prefill_tokens": engine.cached_prefill_tokens,
            "cow_copies": engine.cow_copies,
            # pages the drained engine still holds = the resident prefix cache
            "pages_in_use_after_drain": engine.pages_in_use,
            "live_kv_tokens_after_drain": engine.live_kv_tokens,
        }
        emit(f"serve_prefix_{tag}", wall * 1e6 / max(1, n_requests),
             f"prefill_tok={engine.prefill_tokens}_"
             f"ttft={out[tag]['ttft_ms']:.1f}ms")
    off, on = out["off"], out["on"]
    print(f"[serving] shared-prefix trace ({n_requests} requests): "
          f"prefill tokens {off['prefill_tokens']} -> {on['prefill_tokens']} "
          f"({off['prefill_tokens'] / max(on['prefill_tokens'], 1):.1f}x "
          f"fewer computed), "
          f"mean TTFT {off['ttft_ms']:.1f} -> {on['ttft_ms']:.1f} ms, "
          f"{on['cached_prefill_tokens']} tokens served from cache, "
          f"{on['cow_copies']} CoW tail copies")
    results["shared_prefix"] = out


def run_sampled(model, params, n_requests, slots, results):
    """Same trace served greedy, sampled (fused filter), and sampled with
    the sort-based reference filter (per-request temperature/top-k/top-p,
    seed = uid): tok/s and inter-token latency for each, the fused sampler's
    relative overhead over greedy, how many streams actually diverged from
    greedy (at these settings nearly all should), and ``diverged_streams``
    — fused-vs-reference token mismatches, which the determinism contract
    pins at exactly 0. Each engine serves a tiny warmup trace before its
    timed pass (see ``run_continuous``): the overhead percentages price the
    sampler math per step, not the one-time compile of the sampled jit
    variants."""
    base = make_trace(n_requests, float("inf"))
    sampled = [Request(uid=r.uid, prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens, arrival=r.arrival,
                       sampling=chat_sampling(r.uid))
               for r in base]

    def warmup_trace(stochastic):
        # two short requests whose prompts span >1 prefill chunk: together
        # they hit every jit variant the timed trace uses (chunked +
        # final-chunk prefill, decode, each with this engine's sampling
        # settings), so the timed pass below measures steady-state serving
        rng = np.random.default_rng(4242)
        prompts = rng.integers(5, 500, (2, 72))
        return [Request(uid=9000 + i, prompt=[int(t) for t in prompts[i]],
                        max_new_tokens=6,
                        sampling=chat_sampling(9000 + i) if stochastic
                        else SamplingParams())
                for i in range(2)]

    out = {}
    tokens = {}
    for tag, trace, fused in (("greedy", base, None),
                              ("sampled", sampled, True),
                              ("sampled_ref", sampled, False)):
        times, res, wall, _ = run_continuous(model, params, trace, slots,
                                             prefix_cache=True,
                                             fused_sampling=fused,
                                             warmup=warmup_trace(fused
                                                                 is not None))
        tokens[tag] = {uid: r["tokens"] for uid, r in res.items()}
        out[tag] = summarize(times, wall)
        emit(f"serve_{tag}_decode", wall * 1e6 / max(1, n_requests),
             f"{out[tag]['tok_s']:.1f}tok/s_p50={out[tag]['p50_ms']:.1f}ms")
    out["sampler_overhead_pct"] = 100.0 * (
        out["greedy"]["tok_s"] / max(out["sampled"]["tok_s"], 1e-9) - 1.0)
    out["sampler_overhead_pct_ref"] = 100.0 * (
        out["greedy"]["tok_s"] / max(out["sampled_ref"]["tok_s"], 1e-9) - 1.0)
    out["diverged_requests"] = sum(
        1 for uid in tokens["greedy"]
        if tokens["greedy"][uid] != tokens["sampled"][uid])
    out["diverged_streams"] = sum(
        1 for uid in tokens["sampled"]
        if tokens["sampled"][uid] != tokens["sampled_ref"][uid])
    print(f"[serving] sampled trace ({n_requests} requests, temp=0.8 "
          f"top_k=40 top_p=0.95): greedy {out['greedy']['tok_s']:.1f} tok/s "
          f"vs fused {out['sampled']['tok_s']:.1f} tok/s "
          f"({out['sampler_overhead_pct']:.1f}% sampler overhead, "
          f"ref {out['sampler_overhead_pct_ref']:.1f}%), "
          f"{out['diverged_requests']}/{n_requests} streams diverged from "
          f"greedy, {out['diverged_streams']}/{n_requests} fused-vs-ref "
          f"token mismatches (must be 0)")
    results["sampled"] = out


def run_families(n_requests, slots, results):
    """Hybrid + MoE serving section: the decode-state protocol end to end.

    Serves the same ragged greedy trace through the continuous engine on
    three non-dense smoke archs — mamba2 (pure SSM: constant-size per-slot
    state, no pages in HBM), jamba (hybrid: 1 attention layer per 8, paged
    KV + slot state side by side), and deepseek-moe (token-choice MoE) —
    and records tok/s, inter-token latency, prefill accounting, and the
    per-family prefix-cache gate (SSM-bearing archs force it off; the
    engine records the reason instead of silently no-op'ing)."""
    out = {}
    for name in ("mamba2-1.3b", "jamba-v0.1-52b", "deepseek-moe-16b"):
        arch = smoke_config(name)
        model = build_model(arch)
        params = model.init(jax.random.key(0))
        params = jax.tree.map(lambda p: p.astype(jnp.dtype(arch.dtype)),
                              params)
        trace = make_trace(n_requests, float("inf"), prompt_len=24,
                           gen_range=(8, 32), seed=5)
        times, _, wall, engine = run_continuous(model, params, trace, slots,
                                                prefix_cache=True)
        tag = name.split("-")[0]
        out[name] = {
            **summarize(times, wall),
            "family": arch.family,
            "prefill_tokens": engine.prefill_tokens,
            "prefix_cache": ("off: " + engine.prefix_cache_off_reason
                             if engine.prefix_cache_off_reason else "on"),
        }
        emit(f"serve_family_{tag}", wall * 1e6 / max(1, n_requests),
             f"{out[name]['tok_s']:.1f}tok/s_p50={out[name]['p50_ms']:.1f}ms")
        print(f"[serving] {name} ({arch.family}): "
              f"{out[name]['tok_s']:.1f} tok/s, "
              f"p50 {out[name]['p50_ms']:.1f} ms, "
              f"prefix cache {out[name]['prefix_cache'].split(':')[0]}")
    results["families"] = out


def run_multistep(model, params, n_requests, slots, results):
    """Multi-step compiled decode section: the same mixed greedy/sampled
    trace served at ``decode_steps`` N in {1, 4, 16}. N > 1 moves N decode
    iterations into one on-device ``lax.while_loop`` per host dispatch, so
    the section prices exactly what the tentpole claims: host dispatches per
    decode-emitted token must fall ~Nx (hard bound ``< 1.1 / N``, enforced
    here — it is a deterministic count, not a timing), throughput must not
    regress (``speedup_vs_n1``, gated relatively by check_bench), and token
    streams must stay bit-identical to N=1 (``diverged_streams``, pinned at
    0). Each engine serves a mixed warmup trace first so the timed pass
    compares steady-state serving, not one-time trace+compile cost.

    The trace is decode-heavy (generation lengths 32..64, several horizons
    each): the loop's early exit is GLOBAL, so a request within N tokens of
    its budget truncates the whole dispatch — on traffic shorter than the
    horizon the 1.1/N bound is unattainable by design, and picking N above
    the typical remaining budget buys nothing (docs/SERVING.md covers the
    tuning trade-off)."""
    base = make_trace(n_requests, float("inf"), gen_range=(32, 64))
    trace = [Request(uid=r.uid, prompt=r.prompt,
                     max_new_tokens=r.max_new_tokens, arrival=r.arrival,
                     sampling=chat_sampling(r.uid)
                     if r.uid % 2 else SamplingParams())
             for r in base]

    def warmup_trace():
        # one greedy + one sampled request spanning >1 prefill chunk: hits
        # the chunked/final prefill and both decode variants the timed
        # mixed trace needs, at this engine's horizon
        rng = np.random.default_rng(777)
        prompts = rng.integers(5, 500, (2, 72))
        return [Request(uid=9100 + i, prompt=[int(t) for t in prompts[i]],
                        max_new_tokens=6,
                        sampling=chat_sampling(9100 + i) if i
                        else SamplingParams())
                for i in range(2)]

    out = {}
    tokens = {}
    for n in (1, 4, 16):
        # prefix cache OFF + two spare pages per slot: the horizon
        # pre-allocator only takes FREE pages beyond its preemption reserve
        # and never evicts, so retained prompt pages (these random prompts
        # share nothing — the cache buys zero hits here) or a trace-exact
        # pool would truncate dispatches on page-budget exits instead of
        # letting them run their horizon
        times, res, wall, engine = run_continuous(
            model, params, trace, slots, prefix_cache=False, decode_steps=n,
            warmup=warmup_trace(), spare_pages=2 * slots)
        tokens[n] = {uid: r["tokens"] for uid, r in res.items()}
        # each request's FIRST token comes from its final prefill chunk;
        # everything after is emitted by decode dispatches
        decode_tokens = sum(len(t) for t in tokens[n].values()) - len(trace)
        dpt = engine.decode_dispatches / max(decode_tokens, 1)
        if n > 1 and dpt >= 1.1 / n:
            raise EngineError(
                f"decode_steps={n}: {engine.decode_dispatches} dispatches "
                f"for {decode_tokens} decode tokens = {dpt:.4f} "
                f"dispatches/token, above the 1.1/N={1.1 / n:.4f} bound — "
                "the loop is exiting early every dispatch")
        out[f"n{n}"] = {
            **summarize(times, wall),
            "decode_dispatches": engine.decode_dispatches,
            "decode_steps": engine.steps,
            "decode_tokens": decode_tokens,
            "dispatches_per_token": dpt,
            "exits": dict(engine.decode_exits),
        }
        emit(f"serve_multistep_n{n}", wall * 1e6 / max(1, n_requests),
             f"{out[f'n{n}']['tok_s']:.1f}tok/s_"
             f"{dpt:.3f}dispatch/tok")
    d1 = out["n1"]["decode_dispatches"]
    for n in (4, 16):
        out[f"n{n}"]["host_sync_reduction"] = d1 / max(
            out[f"n{n}"]["decode_dispatches"], 1)
        out[f"n{n}"]["speedup_vs_n1"] = (
            out[f"n{n}"]["tok_s"] / max(out["n1"]["tok_s"], 1e-9))
    out["diverged_streams"] = sum(
        1 for n in (4, 16) for uid in tokens[1]
        if tokens[1][uid] != tokens[n][uid])
    print(f"[serving] multistep trace ({n_requests} requests, mixed "
          f"greedy/sampled): "
          + ", ".join(
              f"N={n} {out[f'n{n}']['tok_s']:.1f} tok/s "
              f"({out[f'n{n}']['dispatches_per_token']:.3f} dispatch/tok)"
              for n in (1, 4, 16))
          + f"; host syncs cut {out['n4']['host_sync_reduction']:.1f}x at "
            f"N=4 / {out['n16']['host_sync_reduction']:.1f}x at N=16, "
            f"{out['diverged_streams']} diverged streams (must be 0)")
    results["multistep"] = out


def run_decode_fusion(model, params, n_requests, slots, results):
    """Decode residual-stream fusion section: the same mixed greedy/sampled
    trace served with ``fused_decode`` off and on (and once more fused at
    decode_steps=4, composing the two tentpoles). Records tok/s each way,
    the fused/unfused throughput ratio, ``diverged_streams`` — fused-vs-
    unfused token mismatches, pinned at exactly 0 by the bit-parity
    contract — and the ANALYTIC per-decode-token HBM bytes the fusion
    removes on an accelerator:

    * ``logits_bytes``: the unfused head writes the f32 ``[1, V]`` logits
      row to HBM and the sampler reads it back; the streaming epilogue
      carries sampling statistics in accumulators instead (2 * 4 * V_padded
      bytes per token).
    * ``residual_bytes``: each fused residual+norm site folds a separate
      hidden-width add (write + read of one ``[1, D]`` row in model dtype)
      into the norm's pass. Sites per stack: every layer's ln2 pair for
      attention/MoE families (the SSM family defers the mixer output
      directly), plus every layer's ln1 except the first of each period
      (whose pre-norm has no pending delta to fold).

    On this CPU bench the ratio prices parity, not speed: bit-identity off
    accelerators is achieved by keeping the fused graph op-identical to the
    unfused one (see ``engine._fused_head``), so tok/s lands near 1x
    (typically ~0.8-1.0x — the op-identical CPU graphs buy no memory win
    and pay a little pair-carry bookkeeping) and the gate is a noise floor;
    the bytes saved are the accelerator story."""
    from repro.models.layers import pad_vocab
    from repro.models.transformer import layer_kinds

    base = make_trace(n_requests, float("inf"))
    trace = [Request(uid=r.uid, prompt=r.prompt,
                     max_new_tokens=r.max_new_tokens, arrival=r.arrival,
                     sampling=chat_sampling(r.uid)
                     if r.uid % 2 else SamplingParams())
             for r in base]

    def warmup_trace():
        rng = np.random.default_rng(555)
        prompts = rng.integers(5, 500, (2, 72))
        return [Request(uid=9200 + i, prompt=[int(t) for t in prompts[i]],
                        max_new_tokens=6,
                        sampling=chat_sampling(9200 + i) if i
                        else SamplingParams())
                for i in range(2)]

    out = {}
    tokens = {}
    for tag, fd, n in (("unfused", False, 1), ("fused", True, 1),
                       ("fused_n4", True, 4)):
        times, res, wall, engine = run_continuous(
            model, params, trace, slots, prefix_cache=False, fused_decode=fd,
            decode_steps=n, warmup=warmup_trace(),
            spare_pages=(2 * slots if n > 1 else 0))
        if fd and not engine.fused_decode:
            raise EngineError("decode_fusion section expects a fusable arch; "
                              f"engine fell back: "
                              f"{engine.fused_decode_off_reason}")
        tokens[tag] = {uid: r["tokens"] for uid, r in res.items()}
        out[tag] = summarize(times, wall)
        emit(f"serve_fusion_{tag}", wall * 1e6 / max(1, n_requests),
             f"{out[tag]['tok_s']:.1f}tok/s_p50={out[tag]['p50_ms']:.1f}ms")
    out["speedup_vs_unfused"] = (
        out["fused"]["tok_s"] / max(out["unfused"]["tok_s"], 1e-9))
    out["diverged_streams"] = sum(
        1 for tag in ("fused", "fused_n4") for uid in tokens["unfused"]
        if tokens["unfused"][uid] != tokens[tag][uid])

    arch = model.arch
    kinds = layer_kinds(arch)
    n_periods = arch.num_layers // len(kinds)
    ln1_sites = arch.num_layers - n_periods
    ln2_sites = 0 if arch.family == "ssm" else arch.num_layers
    dt_bytes = jnp.dtype(arch.dtype).itemsize
    logits_bytes = 2 * 4 * pad_vocab(arch.vocab_size)
    residual_bytes = (ln1_sites + ln2_sites) * 2 * arch.d_model * dt_bytes
    out["hbm_accounting"] = {
        "logits_bytes_per_token": logits_bytes,
        "residual_bytes_per_token": residual_bytes,
        "fused_norm_sites": ln1_sites + ln2_sites,
    }
    out["hbm_bytes_saved_per_token"] = logits_bytes + residual_bytes
    print(f"[serving] decode-fusion trace ({n_requests} requests, mixed "
          f"greedy/sampled): unfused {out['unfused']['tok_s']:.1f} tok/s vs "
          f"fused {out['fused']['tok_s']:.1f} tok/s "
          f"({out['speedup_vs_unfused']:.2f}x; N=4 fused "
          f"{out['fused_n4']['tok_s']:.1f} tok/s), "
          f"{out['diverged_streams']} diverged streams (must be 0), "
          f"{out['hbm_bytes_saved_per_token'] / 1e3:.1f} KB HBM saved per "
          f"decode token on-accelerator "
          f"({out['hbm_accounting']['fused_norm_sites']} fused norm sites + "
          f"the [1, V] logits row)")
    results["decode_fusion"] = out


def run_tp(model, params, n_requests, slots, tp, results):
    """Tensor-parallel section: the same mixed greedy/sampled trace served
    at tp=1 and tp=N. Streams must not diverge (head-sharded TP is an
    execution layout, not a model change); per-device pages/KV bytes and the
    analytic all-reduce wire bytes quantify what sharding buys and costs.

    Runs in fp32, like the cross-engine parity tests: at bf16 the psum's
    reassociated summation flips near-tied argmaxes of this random-init
    smoke model, which would conflate layout rounding noise with real
    divergence — ``diverged_streams`` is the health signal here, and 0 is
    the only healthy value.
    """
    if len(jax.devices()) < tp:
        raise EngineError(
            f"--tp {tp} needs {tp} devices, found {len(jax.devices())} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count)")
    params = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    model = build_model(dataclasses.replace(model.arch, dtype="float32"))
    base = make_trace(n_requests, float("inf"))
    trace = [Request(uid=r.uid, prompt=r.prompt,
                     max_new_tokens=r.max_new_tokens, arrival=r.arrival,
                     sampling=chat_sampling(r.uid)
                     if r.uid % 2 else SamplingParams())
             for r in base]
    out = {"tp": tp}
    tokens = {}
    for degree in (1, tp):
        times, res, wall, engine = run_continuous(model, params, trace,
                                                  slots, prefix_cache=True,
                                                  tp=degree)
        tokens[degree] = {uid: r["tokens"] for uid, r in res.items()}
        tag = f"tp{degree}"
        out[tag] = summarize(times, wall)
        if degree > 1:
            out[tag].update(engine.tp_stats())
        emit(f"serve_{tag}_decode", wall * 1e6 / max(1, n_requests),
             f"{out[tag]['tok_s']:.1f}tok/s_p50={out[tag]['p50_ms']:.1f}ms")
    out["diverged_streams"] = sum(
        1 for uid in tokens[1] if tokens[1][uid] != tokens[tp][uid])
    tps = out[f"tp{tp}"]
    print(f"[serving] tp={tp} trace ({n_requests} requests): "
          f"tp1 {out['tp1']['tok_s']:.1f} tok/s vs tp{tp} "
          f"{tps['tok_s']:.1f} tok/s, "
          f"{out['diverged_streams']}/{n_requests} streams diverged, "
          f"{tps['collective_bytes_per_device'] / 1e6:.2f} MB all-reduced "
          f"and {tps['per_device']['kv_bytes'] / 1e6:.2f} MB KV "
          f"({tps['per_device']['pages_in_use']} pages) per device")
    results["tensor_parallel"] = out


def run(arch_name="llama3.2-3b", n_requests=16, slots=4,
        rates=(4.0, 16.0, float("inf")), json_path=None, tp=1,
        tp_only=False, sampled_only=False, multistep_only=False,
        decode_fusion_only=False) -> dict:
    arch = smoke_config(arch_name)
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    params = jax.tree.map(lambda p: p.astype(jnp.dtype(arch.dtype)), params)

    results = {"arch": arch_name, "n_requests": n_requests, "slots": slots,
               "backend": jax.default_backend(), "rates": {}}
    _ENGINE_STATS.clear()
    if sampled_only:
        run_sampled(model, params, n_requests, slots, results)
    elif multistep_only:
        run_multistep(model, params, n_requests, slots, results)
    elif decode_fusion_only:
        run_decode_fusion(model, params, n_requests, slots, results)
    elif not tp_only:
        run_rates(model, params, n_requests, slots, rates, results)
        run_shared_prefix(model, params, n_requests, slots, results)
        run_sampled(model, params, n_requests, slots, results)
        run_families(n_requests, slots, results)
        run_multistep(model, params, n_requests, slots, results)
        run_decode_fusion(model, params, n_requests, slots, results)
    if tp > 1:
        run_tp(model, params, n_requests, slots, tp, results)
    # jit-cache closure census across every engine the run built: ``excess``
    # counts traces beyond one-per-variant (i.e. recompiles after warmup)
    # and must be 0 — check_bench gates on it with direction "zero"
    results["recompiles"] = {
        "engines": len(_ENGINE_STATS),
        "variants": sum(s["variants"] for s in _ENGINE_STATS),
        "traces": sum(s["traces"] for s in _ENGINE_STATS),
        "excess": sum(s["excess"] for s in _ENGINE_STATS),
    }
    rc = results["recompiles"]
    print(f"[serving] recompiles: {rc['engines']} engine(s), "
          f"{rc['variants']} jit variant(s), {rc['traces']} trace(s) — "
          f"{rc['excess']} recompile(s) after warmup")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
        print(f"[serving] wrote {json_path}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--tp", type=int, default=1,
                    help="add a tensor-parallel section at this degree "
                         "(needs that many devices)")
    ap.add_argument("--tp-only", action="store_true",
                    help="run ONLY the tensor-parallel section (it serves "
                         "tp=1 itself for the comparison) — the multidevice "
                         "CI job uses this to avoid re-running the "
                         "single-device sections the tier1 job covers")
    ap.add_argument("--sampled-only", action="store_true",
                    help="run ONLY the sampled-traffic section (greedy vs "
                         "fused vs reference filter) — the nightly CI job "
                         "uses this with a larger trace to watch the "
                         "sampler tax without re-running the full bench")
    ap.add_argument("--multistep-only", action="store_true",
                    help="run ONLY the multi-step compiled decode section "
                         "(decode_steps N in {1,4,16}) — the nightly CI job "
                         "uses this with a larger trace to watch host-sync "
                         "reduction without re-running the full bench")
    ap.add_argument("--decode-fusion-only", action="store_true",
                    help="run ONLY the decode residual-stream fusion section "
                         "(fused_decode off/on + fused at decode_steps=4) — "
                         "the nightly CI job uses this with a larger trace "
                         "to watch the fused/unfused ratio and the pinned "
                         "zero-divergence gate without re-running the full "
                         "bench")
    ap.add_argument("--json", default="",
                    help="also write the full results dict to this path")
    args = ap.parse_args()
    if args.tp_only and args.tp <= 1:
        ap.error("--tp-only requires --tp > 1")
    if sum((args.tp_only, args.sampled_only, args.multistep_only,
            args.decode_fusion_only)) > 1:
        ap.error("--tp-only/--sampled-only/--multistep-only/"
                 "--decode-fusion-only are mutually exclusive")
    print("name,us_per_call,derived")
    try:
        run(args.arch, args.requests, args.slots, json_path=args.json or None,
            tp=args.tp, tp_only=args.tp_only, sampled_only=args.sampled_only,
            multistep_only=args.multistep_only,
            decode_fusion_only=args.decode_fusion_only)
    except Exception as e:  # noqa: BLE001 — any engine failure must fail CI
        # no JSON is written on this path: a partial artifact uploaded by CI
        # reads as a healthy run with silently missing sections
        print(f"[serving] ENGINE ERROR: {type(e).__name__}: {e}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
