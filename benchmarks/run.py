# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import traceback


def main() -> None:
    from . import (breakdown, distributed, fusion_gemm, fusion_kernels,
                   gemm_table, nongemm_ai, roofline_table, serving, sweeps)
    print("name,us_per_call,derived")
    for mod in (breakdown, gemm_table, nongemm_ai, sweeps, distributed,
                fusion_kernels, fusion_gemm, roofline_table, serving):
        try:
            mod.run()
        except Exception:  # noqa: BLE001 — a failing table must not hide others
            traceback.print_exc()
            print(f"{mod.__name__},0.0,ERROR")


if __name__ == '__main__':
    main()
