"""Paper Table 3 + Fig 7: every BERT GEMM's dims and arithmetic intensity,
for FWD / BWD-grad-activation / BWD-grad-weight."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import analytical

from .common import emit


def run() -> None:
    bert = get_config("bert-large")
    for phase in ("fwd", "bwd_act", "bwd_w"):
        for g in analytical.transformer_gemms(bert, 32, 128, phase):
            if g.layer == "head":
                continue
            emit(f"table3/{phase}/{g.name}", 0.0,
                 f"M={g.m};N={g.n};K={g.k};batch={g.batch};"
                 f"ops_per_byte={g.intensity(4):.1f}")
    # Fig 7's claim: FC GEMMs' intensity >> attention B-GEMMs'
    gs = {g.name: g for g in analytical.transformer_gemms(bert, 32, 128)}
    fc = gs["fc1"].intensity(4)
    bg = gs["attn_score"].intensity(4)
    emit("fig7/intensity_ratio", 0.0,
         f"fc={fc:.1f};attn_bgemm={bg:.1f};ratio={fc/bg:.1f}")
