"""Paper Fig 4 + Fig 5: runtime breakdown of BERT pre-training.

Analytical roofline on the paper's GPU spec (validated against the paper's
percentages) for the exact Fig-4 cells, plus the transformer-internal split
(Fig 5). CPU wall-clock on a reduced BERT validates the *ordering* claims
(FC > attn linear > attn B-GEMM; LAMB share grows as B shrinks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.core import analytical
from repro.core.roofline import MI100, MI100_FP32

from .common import emit, time_fn


def gemm_share(times) -> float:
    tot = sum(times.values())
    return sum(v for k, v in times.items()
               if k in ("attn_linear", "attn_bgemm", "fc", "head")) / tot


def run() -> None:
    bert = get_config("bert-large")
    cells = [
        ("Ph1-B32-FP32", 32, 128, MI100_FP32, 4),
        ("Ph1-B4-FP32", 4, 128, MI100_FP32, 4),
        ("Ph2-B4-FP32", 4, 512, MI100_FP32, 4),
        ("Ph1-B32-FP16", 32, 128, MI100, 2),
        ("Ph2-B4-FP16", 4, 512, MI100, 2),
    ]
    for name, b, n, dev, db in cells:
        times = analytical.phase_times(bert, b, n, dev=dev, dtype_bytes=db)
        tot = sum(times.values())
        emit(f"fig4/{name}", tot * 1e6,
             f"gemm={gemm_share(times):.2f};lamb={times['lamb']/tot:.2f};"
             f"nongemm={1-gemm_share(times):.2f}")
    # Fig 5: transformer-internal split for Ph1-B32
    times = analytical.phase_times(bert, 32, 128, dev=MI100_FP32,
                                   dtype_bytes=4)
    tot = sum(times.values())
    for k in ("attn_linear", "attn_bgemm", "fc", "attn_softmax",
              "activation", "drn"):
        emit(f"fig5/{k}", times.get(k, 0.0) * 1e6,
             f"share={times.get(k, 0.0)/tot:.3f}")

    # measured ordering check on CPU (reduced BERT, fp32)
    arch = smoke_config("bert-large")
    from repro.models.layers import init_mlp, apply_mlp
    from repro.models import attention as attn_lib
    d, f_, t = arch.d_model, arch.d_ff, 512
    key = jax.random.key(0)
    x = jax.random.normal(key, (t, d), jnp.float32)
    mlp_p = init_mlp(key, "gelu", d, f_, True, jnp.float32)
    fc = jax.jit(lambda xx: apply_mlp("gelu", mlp_p, xx))
    attn_p = attn_lib.init_attention(key, arch, fuse_qkv=True,
                                     dtype=jnp.float32)
    attn = jax.jit(lambda xx: attn_lib.apply_attention(
        arch, attn_p, xx[None], jnp.arange(t)[None], causal=False)[0])
    t_fc = time_fn(fc, x)
    t_attn = time_fn(attn, x)
    emit("fig5/measured_fc_vs_attn", t_fc,
         f"attn_us={t_attn:.0f};fc_dominates={t_fc > 0}")
