"""§Roofline deliverable: the (arch x shape) table from the dry-run artifacts."""
from __future__ import annotations

import json
from pathlib import Path

from .common import emit

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run(tag: str = "baseline", mesh: str = "single") -> None:
    for path in sorted(DRYRUN.glob(f"{tag}__{mesh}__*.json")):
        rec = json.loads(path.read_text())
        name = f"roofline/{rec['arch']}x{rec['shape']}"
        if rec.get("skip"):
            emit(name, 0.0, "SKIP")
            continue
        r = rec["roofline"]
        m = rec["memory"]
        emit(name, r["step_s"] * 1e6,
             f"dominant={r['dominant']};compute_ms={r['compute_s']*1e3:.1f};"
             f"memory_ms={r['memory_s']*1e3:.1f};"
             f"collective_ms={r['collective_s']*1e3:.1f};"
             f"useful={r['useful_ratio']:.2f};"
             f"fraction={r['peak_fraction']:.3f};"
             f"peak_gb={m['peak_bytes']/1e9:.1f}")
