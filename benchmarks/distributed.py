"""Paper Fig 12: single / data-parallel (w,w/o overlap) / 2,8-way model parallel."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import distmodel

from .common import emit


def run() -> None:
    bert = get_config("bert-large")
    profiles = distmodel.figure12(bert)
    s1 = profiles["S1 (single, B=16)"].total
    for name, prof in profiles.items():
        b = prof.breakdown()
        tot = prof.total
        emit(f"fig12/{name.split(' ')[0]}", tot * 1e6,
             f"comm_share={prof.comm_time/tot:.3f};"
             f"lamb_share={b.get('lamb',0)/tot:.3f};"
             f"vs_single={tot/s1:.2f}")
