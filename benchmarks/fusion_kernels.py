"""Paper Fig 13: kernel-fusion impact — LayerNorm chain and the optimizer.

Measured CPU wall-clock, *unfused* (each phase a separate jit call — the
paper's separate-GPU-kernel analogue, paying a dispatch boundary + HBM
round-trip per phase) vs *fused* (one jit). Memory-traffic ratios come from
the HLO cost engine on the compiled programs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import characterize
from repro.optim import adamw as adamw_mod
from repro.optim import lamb as lamb_mod

from .common import emit, time_fn


def _traffic(fn, *args) -> float:
    text = jax.jit(fn).lower(*args).compile().as_text()
    return characterize.analyze_text(text, 1).bytes


def run() -> None:
    # ---- LayerNorm(+residual) fusion -----------------------------------------
    r, d = 4096, 1024
    x = jax.random.normal(jax.random.key(0), (r, d), jnp.float32)
    res = jax.random.normal(jax.random.key(1), (r, d), jnp.float32)
    scale = jnp.ones((d,))
    bias = jnp.zeros((d,))

    add = jax.jit(lambda a, b: a + b)
    mean = jax.jit(lambda h: jnp.mean(h, -1, keepdims=True))
    var = jax.jit(lambda h, mu: jnp.mean((h - mu) ** 2, -1, keepdims=True))
    norm = jax.jit(lambda h, mu, v: (h - mu) * jax.lax.rsqrt(v + 1e-5))
    affine = jax.jit(lambda y: y * scale + bias)

    def unfused(a, b):
        h = add(a, b)
        mu = mean(h)
        v = var(h, mu)
        return affine(norm(h, mu, v))

    from repro.kernels.fused_layernorm import ref as lnref
    fused = jax.jit(lambda a, b: lnref.fused_residual_layernorm(
        a, b, scale, bias))

    t_u = time_fn(unfused, x, res)
    t_f = time_fn(fused, x, res)
    b_f = _traffic(lambda a, b: lnref.fused_residual_layernorm(
        a, b, scale, bias), x, res)
    b_u = 5 * 2 * r * d * 4  # 5 phases x read+write
    emit("fig13/layernorm_unfused", t_u, f"kernels=5;traffic_gb={b_u/1e9:.3f}")
    emit("fig13/layernorm_fused", t_f,
         f"kernels=1;traffic_gb={b_f/1e9:.3f};speedup={t_u/t_f:.2f};"
         f"traffic_ratio={b_u/max(b_f,1):.1f}")

    # ---- optimizer fusion (paper uses Adam) -----------------------------------
    import numpy as np
    nt, sz = 24, 65536  # 24 layer-tensors
    params = {f"w{i}": jax.random.normal(jax.random.key(i), (sz,))
              for i in range(nt)}
    grads = jax.tree.map(lambda p: p * 0.01, params)
    cfg = adamw_mod.AdamWConfig(zero1=False)
    state = adamw_mod.init(cfg, params)

    fused_upd = jax.jit(lambda g, s, p: adamw_mod.update(cfg, g, s, p))

    # unfused: each elementwise stage of Adam as its own jit call per tensor
    m_ = jax.jit(lambda m, g: 0.9 * m + 0.1 * g)
    v_ = jax.jit(lambda v, g: 0.999 * v + 0.001 * g * g)
    u_ = jax.jit(lambda m, v: m / (jnp.sqrt(v) + 1e-8))
    w_ = jax.jit(lambda w, u: w - 1e-3 * (u + 0.01 * w))

    def unfused_upd(g, s, p):
        out = {}
        for k in p:
            mm = m_(s["m"][k], g[k])
            vv = v_(s["v"][k], g[k])
            out[k] = w_(p[k], u_(mm, vv))
        return out

    t_f = time_fn(fused_upd, grads, state, params)
    t_u = time_fn(unfused_upd, grads, state, params)
    emit("fig13/adam_unfused", t_u, f"kernels={4*nt}")
    emit("fig13/adam_fused", t_f,
         f"kernels=1;speedup={t_u/t_f:.2f}")

    # LAMB fused reference (the paper's actual optimizer), for scale
    lcfg = lamb_mod.LambConfig(zero1=False, master_weights=False)
    lstate = lamb_mod.init(lcfg, params)
    lamb_upd = jax.jit(lambda g, s, p: lamb_mod.update(lcfg, g, s, p))
    t_l = time_fn(lamb_upd, grads, lstate, params)
    emit("fig13/lamb_fused", t_l, f"tensors={nt}")
