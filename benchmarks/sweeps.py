"""Paper Fig 9 (mini-batch sweep) + Fig 10 (hidden-dim sweep)."""
from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.core import analytical
from repro.core.roofline import MI100_FP32

from .common import emit


def run() -> None:
    bert = get_config("bert-large")
    for b in (4, 8, 16, 32):
        times = analytical.phase_times(bert, b, 128, dev=MI100_FP32,
                                       dtype_bytes=4)
        tot = sum(times.values())
        emit(f"fig9/B{b}", tot * 1e6,
             f"lamb_share={times['lamb']/tot:.3f};"
             f"fc_share={times['fc']/tot:.3f}")
    for width in (768, 1024, 2048, 4096):
        arch = dataclasses.replace(bert, d_model=width, d_ff=4 * width,
                                   head_dim=width // bert.num_heads)
        times = analytical.phase_times(arch, 32, 128, dev=MI100_FP32,
                                       dtype_bytes=4)
        tot = sum(times.values())
        gemm = sum(v for k, v in times.items()
                   if k in ("attn_linear", "attn_bgemm", "fc", "head")) / tot
        emit(f"fig10/d{width}", tot * 1e6,
             f"gemm_share={gemm:.3f};lamb_share={times['lamb']/tot:.3f}")
