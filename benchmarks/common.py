"""Timing + CSV helpers shared by the per-figure benchmarks."""
from __future__ import annotations

import time
from typing import Callable, List

import jax

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row)


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock microseconds per call of a jitted fn."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    samples.sort()
    return samples[len(samples) // 2] * 1e6
