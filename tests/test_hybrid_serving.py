"""Decode-state protocol: the continuous engine serving SSM (mamba2-1.3b),
hybrid (jamba-v0.1-52b), and MoE (deepseek-moe-16b) smoke archs.

What must hold, per the protocol's contract:

- **Cross-engine parity.** Greedy and seeded-sampled streams are
  token-identical between the static engine and the continuous engine for
  all three families, including slot recycling and forced-replay preemption
  (an SSM mixer's state is recomputed by re-prefilling the victim's context,
  so resume is token-identical even though the state is not page-shaped).
- **Cross-tp parity.** tp ∈ {1, 2} streams are identical for the hybrid and
  expert-parallel MoE paths (subprocess with 4 forced host devices, the
  ``test_tp_serving.py`` pattern), including preemption mid-decode, and
  tp=4 on the 2-KV-head llama smoke config exercises KV-head replication.
- **Prefix-cache gate.** SSM-bearing archs gate prefix caching off with an
  engine-level reason and a per-request result stat — never a silent no-op —
  and ``launch.serve`` rejects an explicit ``--prefix-cache`` up front.

MoE parity notes: capacity drops are batch-shape-dependent (chunked prefill
re-buckets capacity per chunk, and chunk padding routes too), so the parity
fixtures raise ``capacity_factor`` until no token drops — the same choice
``test_serve_consistency.py`` pins. Parity runs in fp32, like every other
cross-engine fixture: bf16 reassociation flips near-tied draws of
random-init smoke models.
"""
import dataclasses
import functools
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.models import build_model
from repro.parallel import sharding as sh
from repro.serving import ContinuousEngine, Request, sample_tokens
from repro.serving.sampling import SamplingParams

ROOT = Path(__file__).resolve().parents[1]

FAMILIES = ["mamba2-1.3b", "jamba-v0.1-52b", "deepseek-moe-16b"]


@functools.lru_cache(maxsize=None)
def _fp32_model(name):
    arch = smoke_config(name)
    arch = dataclasses.replace(arch, dtype="float32", param_dtype="float32")
    if arch.moe is not None:
        arch = dataclasses.replace(
            arch, moe=dataclasses.replace(arch.moe, capacity_factor=8.0))
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    return arch, model, params


def _static_sampled(model, params, prompts, gens, sps):
    """Per-request static decode (batch 1) through the shared sampler: the
    reference stream the continuous engine must reproduce draw for draw."""
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)
    sample = jax.jit(sample_tokens)

    def draw(logits, sp, pos):
        return int(sample(logits,
                          jnp.asarray([sp.seed], jnp.uint32),
                          jnp.asarray([pos], jnp.int32),
                          jnp.asarray([sp.temperature], jnp.float32),
                          jnp.asarray([sp.top_k], jnp.int32),
                          jnp.asarray([sp.top_p], jnp.float32))[0])

    out = []
    for prompt, glen, sp in zip(prompts, gens, sps):
        plen = len(prompt)
        caches = model.init_caches(None, 1, plen + glen)
        logits, caches = prefill(params, caches,
                                 {"tokens": jnp.asarray([prompt])})
        tok = draw(logits[:, -1], sp, plen)
        ids = [tok]
        for s in range(glen - 1):
            logits, caches = decode(
                params, caches,
                {"tokens": jnp.asarray([[tok]]),
                 "positions": jnp.full((1,), plen + s, jnp.int32)})
            tok = draw(logits[:, -1], sp, plen + 1 + s)
            ids.append(tok)
        out.append(ids)
    return out


# -------------------------------------------------------- cross-engine parity ---

@pytest.mark.parametrize("name", FAMILIES)
def test_continuous_matches_static_greedy(name):
    arch, model, params = _fp32_model(name)
    rng = np.random.default_rng(3)
    prompts = [list(map(int, rng.integers(5, arch.vocab_size,
                                          rng.integers(6, 14))))
               for _ in range(4)]
    gens = [6, 11, 4, 9]
    ref = _static_sampled(model, params, prompts, gens,
                          [SamplingParams()] * 4)
    engine = ContinuousEngine(model, params, num_slots=4, num_pages=48,
                              page_size=8, max_seq_len=64)
    res = engine.run([Request(uid=i, prompt=prompts[i], max_new_tokens=gens[i])
                      for i in range(4)])
    for i in range(4):
        assert res[i]["tokens"] == ref[i], f"request {i} diverged"
    assert engine.live_kv_tokens == 0          # all pages recycled


@pytest.mark.parametrize("name", FAMILIES)
def test_sampled_parity_under_recycling_and_preemption(name):
    """A pool too small for every request: slot recycling and forced-replay
    preemption (which for SSM mixers recomputes the recurrent state by
    re-prefilling) must not change one sampled token vs the static
    reference."""
    arch, model, params = _fp32_model(name)
    rng = np.random.default_rng(37)
    prompts = [list(map(int, rng.integers(5, arch.vocab_size, 12)))
               for _ in range(5)]
    gens = [4, 16, 7, 12, 9]
    sps = [SamplingParams(temperature=0.8, top_k=0 if i % 2 else 20,
                          top_p=0.95, seed=1000 + i) for i in range(5)]
    ref = _static_sampled(model, params, prompts, gens, sps)
    engine = ContinuousEngine(model, params, num_slots=2, num_pages=10,
                              page_size=4, max_seq_len=32,
                              prefix_cache=False)
    res = engine.run([Request(uid=i, prompt=prompts[i], max_new_tokens=gens[i],
                              sampling=sps[i]) for i in range(5)])
    for i in range(5):
        assert res[i]["tokens"] == ref[i], f"request {i} diverged"
    assert engine.prefills > 5                 # preemption actually happened
    assert engine.scheduler.allocator.used_count == 0


# ---------------------------------------------------------- prefix-cache gate ---

def test_prefix_cache_gated_off_for_ssm_with_stat():
    """Asking an SSM-bearing engine for prefix caching must gate it off with
    an engine-level reason AND a per-request result stat — the explicit
    "this was not a silent no-op" contract."""
    arch, model, params = _fp32_model("mamba2-1.3b")
    engine = ContinuousEngine(model, params, num_slots=2, num_pages=32,
                              page_size=8, max_seq_len=64, prefix_cache=True)
    assert engine.scheduler.prefix is None
    assert "page-decomposable" in engine.prefix_cache_off_reason
    prompt = list(range(5, 17))
    res = engine.run([Request(uid=0, prompt=prompt, max_new_tokens=4),
                      Request(uid=1, prompt=prompt, max_new_tokens=4)])
    for uid in (0, 1):
        assert res[uid]["cached_prefill_tokens"] == 0
        assert res[uid]["prefix_cache"].startswith("off: ")
    # explicitly asking for OFF is the caller's choice, not a gate
    quiet = ContinuousEngine(model, params, num_slots=2, num_pages=32,
                             page_size=8, max_seq_len=64, prefix_cache=False)
    assert quiet.prefix_cache_off_reason is None


def test_attention_archs_keep_per_request_cache_stat():
    """The per-request ``cached_prefill_tokens`` stat is universal: on an
    attention arch with the cache ON, a repeated prompt's second request
    reports its cached tokens and carries no gate marker."""
    arch, model, params = _fp32_model("deepseek-moe-16b")
    prompt = list(range(5, 5 + 16))
    engine = ContinuousEngine(model, params, num_slots=2, num_pages=32,
                              page_size=8, max_seq_len=64, prefix_cache=True)
    res = engine.run([Request(uid=0, prompt=prompt, max_new_tokens=3),
                      Request(uid=1, prompt=prompt, max_new_tokens=3)])
    assert res[0]["tokens"] == res[1]["tokens"]
    assert "prefix_cache" not in res[0]
    assert res[1]["cached_prefill_tokens"] > 0


def test_serve_cli_rejects_prefix_cache_for_ssm(capsys):
    from repro.launch import serve
    for name in ("mamba2-1.3b", "jamba-v0.1-52b"):
        with pytest.raises(SystemExit):
            serve.main(["--arch", name, "--smoke", "--engine", "continuous",
                        "--prefix-cache"])
        err = capsys.readouterr().err
        assert "not page-decomposable" in err
    # the static engine has no prefix cache: the flag must stay accepted
    # there (it was before this gate existed) and simply do nothing
    out = serve.main(["--arch", "mamba2-1.3b", "--smoke", "--engine",
                      "static", "--prefix-cache", "--batch", "1",
                      "--prompt-len", "8", "--gen-len", "2"])
    assert out["tokens"].shape == (1, 2)
    # with NO flag, the continuous CLI must route through the ENGINE's gate
    # so the off-reason is recorded, not silently pre-resolved to off here
    out = serve.main(["--arch", "mamba2-1.3b", "--smoke", "--engine",
                      "continuous", "--batch", "1",
                      "--prompt-len", "8", "--gen-len", "2"])
    assert "not page-decomposable" in out["prefix_cache_off_reason"]


def test_serve_cli_names_servable_families(capsys):
    """An unservable family must fail up front with a message naming
    SERVABLE_FAMILIES, not as an assertion deep in the engine."""
    from repro.launch import serve
    from repro.serving.engine import SERVABLE_FAMILIES
    with pytest.raises(SystemExit):
        serve.main(["--arch", "whisper-base", "--smoke",
                    "--engine", "continuous"])
    err = capsys.readouterr().err
    for fam in SERVABLE_FAMILIES:
        assert fam in err
    assert "static" in err


# ------------------------------------------------------------- sharding specs ---

def test_serving_state_specs_mixed_stack():
    """Decode-state pspecs: attention page pools head-sharded on ndim-2,
    mamba slot-state (conv tail + SSD state) replicated."""
    from repro.models import transformer as tf

    arch = smoke_config("jamba-v0.1-52b")
    pools = jax.eval_shape(
        lambda: tf.init_serving_state(arch, 8, 4, 2, jnp.float32))
    specs = sh.paged_pool_pspecs(pools)
    flat = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda s: isinstance(s, P))
    names = set()
    for kp, spec in flat:
        name = kp[-1].key
        names.add(name)
        if name in ("k", "v"):
            assert spec[-2] == "model", (name, spec)
            assert all(a is None for i, a in enumerate(spec)
                       if i != len(spec) - 2)
        else:
            assert name in ("conv", "state")
            assert all(a is None for a in spec), (name, spec)
    assert {"k", "v", "conv", "state"} <= names


def test_serving_param_pspecs_expert_parallel_layout():
    """Routed experts shard E-major; the router and mamba mixers stay
    replicated; shared experts take the dense column/row-parallel rules."""
    from repro.serving.engine import _split_fused_qkv

    for name in ("deepseek-moe-16b", "jamba-v0.1-52b"):
        arch = smoke_config(name)
        model = build_model(arch)
        params = jax.eval_shape(lambda m=model, a=arch: _split_fused_qkv(
            m.init(jax.random.key(0)), a))
        specs = sh.serving_param_pspecs(params)
        seen = {}
        for kp, spec in jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda s: isinstance(s, P)):
            path = tuple(k.key for k in kp if hasattr(k, "key"))
            if "experts" in path[:-1]:
                assert spec[-3] == "model" and spec[-2] is None \
                    and spec[-1] is None, (path, spec)
            elif "shared" in path[:-1]:
                if path[-1] in ("w1", "w3"):
                    assert spec[-1] == "model", (path, spec)
                elif path[-1] == "w2":
                    assert spec[-2] == "model", (path, spec)
            seen.setdefault(path[-1], spec)
        assert all(a is None for a in seen["router"])
        if "in_proj" in seen:                  # jamba's mamba mixers
            for mamba_leaf in ("in_proj", "out_proj", "conv", "A_log"):
                assert all(a is None for a in seen[mamba_leaf]), mamba_leaf


# ------------------------------------------------------------ tp ∈ {1, 2, 4} ----

def _run_subprocess(body: str):
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'\n"
        "os.environ['JAX_PLATFORMS'] = 'cpu'\n" + body)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=ROOT, timeout=540,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    return r.stdout


def test_tp_parity_hybrid_moe_and_kv_replication():
    """One subprocess covers the tp acceptance matrix: jamba (hybrid) and
    mamba2 (pure SSM) token-identical across tp ∈ {1, 2}; expert-parallel
    deepseek-moe identical across tp ∈ {1, 2} under a starved pool forcing
    preemption mid-decode; llama's 2-KV-head smoke config at tp=4
    exercising KV-head replication. Collective accounting must be positive
    exactly where psums exist — and zero for the pure-SSM stack, whose
    mixers are replicated."""
    out = _run_subprocess(r"""
import dataclasses
import jax, numpy as np
from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import ContinuousEngine, Request
from repro.serving.sampling import SamplingParams

def fp32(name):
    arch = smoke_config(name)
    arch = dataclasses.replace(arch, dtype="float32", param_dtype="float32")
    if arch.moe is not None:
        arch = dataclasses.replace(
            arch, moe=dataclasses.replace(arch.moe, capacity_factor=8.0))
    model = build_model(arch)
    return arch, model, model.init(jax.random.key(0))

def reqs_for(arch, seed, n=4, plen=(4, 14), gens=None):
    rng = np.random.default_rng(seed)
    prompts = [list(map(int, rng.integers(5, arch.vocab_size,
                                          int(rng.integers(*plen)))))
               for _ in range(n)]
    gens = gens or [int(rng.integers(3, 9)) for _ in range(n)]
    sps = [SamplingParams() if i % 2 == 0 else
           SamplingParams(temperature=0.8, top_k=12, top_p=0.9, seed=100 + i)
           for i in range(n)]
    return [Request(uid=i, prompt=prompts[i], max_new_tokens=gens[i],
                    sampling=sps[i]) for i in range(n)]

def serve(model, params, reqs, **kw):
    eng = ContinuousEngine(model, params, **kw)
    res = eng.run(list(reqs))
    return eng, [res[i]["tokens"] for i in range(len(reqs))]

# hybrid (jamba): mixed greedy/sampled, tp=1 vs tp=2, roomy then starved pool
arch, model, params = fp32("jamba-v0.1-52b")
reqs = reqs_for(arch, 7)
kw = dict(num_slots=4, num_pages=64, page_size=8, max_seq_len=64)
e1, r1 = serve(model, params, reqs, tp=1, **kw)
e2, r2 = serve(model, params, reqs, tp=2, **kw)
assert r1 == r2, (r1, r2)
assert e1.collective_bytes == 0 and e2.collective_bytes > 0
assert e2.tp_stats()["per_device"]["ssm_state_bytes"] > 0
starved = reqs_for(arch, 37, n=5, plen=(12, 13), gens=[4, 16, 7, 12, 9])
skw = dict(num_slots=2, num_pages=10, page_size=4, max_seq_len=40)
p1, s1 = serve(model, params, starved, tp=1, **skw)
p2, s2 = serve(model, params, starved, tp=2, **skw)
assert s1 == s2, (s1, s2)
assert p2.prefills > 5, "pool was not starved enough to preempt"

# pure SSM (mamba2): tp is all-replicated execution — parity, zero psums
arch, model, params = fp32("mamba2-1.3b")
reqs = reqs_for(arch, 11)
e1, r1 = serve(model, params, reqs, tp=1, **kw)
e2, r2 = serve(model, params, reqs, tp=2, **kw)
assert r1 == r2, (r1, r2)
assert e2.collective_bytes == 0, "replicated mamba stack psums nothing"

# expert-parallel MoE (deepseek): starved pool -> preemption mid-decode
arch, model, params = fp32("deepseek-moe-16b")
reqs = reqs_for(arch, 13, n=5, plen=(12, 13), gens=[4, 16, 7, 12, 9])
m1, t1 = serve(model, params, reqs, tp=1, prefix_cache=False, **skw)
m2, t2 = serve(model, params, reqs, tp=2, prefix_cache=False, **skw)
assert t1 == t2, (t1, t2)
assert m2.prefills > 5, "pool was not starved enough to preempt"
assert m2.collective_bytes > 0

# KV-head replication: llama smoke has 2 KV heads; tp=4 replicates each
# across 2 shards and must stay token-identical to tp=1
arch, model, params = fp32("llama3.2-3b")
assert arch.num_kv_heads == 2, arch.num_kv_heads
reqs = reqs_for(arch, 17)
l1, a1 = serve(model, params, reqs, tp=1, **kw)
l4, a4 = serve(model, params, reqs, tp=4, **kw)
assert a1 == a4, (a1, a4)
st = l4.tp_stats()
assert st["kv_head_replication"] == 2
# replication's honest cost: past tp == Hkv, per-device KV bytes stop
# shrinking — tp=4 (1 of 2 heads each, replicated twice) holds exactly what
# tp=2 (1 of 2 heads each) holds
l2, a2 = serve(model, params, reqs, tp=2, **kw)
assert a2 == a1
assert st["per_device"]["kv_bytes"] == l2.tp_stats()["per_device"]["kv_bytes"]
assert st["per_device"]["kv_bytes"] > 0
print("PROTOCOL_TP_PARITY_OK")
""")
    assert "PROTOCOL_TP_PARITY_OK" in out


def test_tp_rejects_indivisible_expert_count():
    """Expert-parallel TP needs tp | num_experts; the error must fire at
    construction and name the expert count."""
    arch = smoke_config("deepseek-moe-16b")
    arch = dataclasses.replace(
        arch, moe=dataclasses.replace(arch.moe, num_experts=3, top_k=2))
    model = build_model(arch)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    with pytest.raises(AssertionError, match="expert"):
        ContinuousEngine(model, params, tp=2)


def test_tp_rejects_unreplicatable_kv_heads():
    """tp must divide Hkv or be a multiple of it; tp=3 on 2 KV heads is
    neither and must fail before any mesh is built."""
    arch = smoke_config("llama3.2-3b")        # 4 query heads, 2 kv heads
    arch = dataclasses.replace(arch, num_heads=6)
    model = build_model(arch)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    with pytest.raises(AssertionError, match="KV heads"):
        ContinuousEngine(model, params, tp=3)
