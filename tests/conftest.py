import os

# smoke tests and benches must see ONE device (the dry-run sets 512 itself,
# in its own process); keep XLA quiet and deterministic
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)
