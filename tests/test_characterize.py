"""HLO cost engine: trip-count multiplication, dot pricing, collective parse —
validated against XLA cost_analysis on unrolled graphs and known-flop programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import characterize, hlotext


def _cost(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return characterize.analyze_text(c.as_text(), 1), c


def _xla_flops(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # jax 0.4.x returns [dict]
        ca = ca[0]
    return ca["flops"]


def test_dot_flops_exact():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 256), jnp.float32)
    cost, compiled = _cost(lambda x, y: x @ y, a, b)
    expected = 2 * 64 * 128 * 256
    assert abs(cost.flops - expected) / expected < 0.01
    xla = _xla_flops(compiled)
    assert abs(cost.flops - xla) / expected < 0.05


def test_scan_trip_count_multiplication():
    """XLA counts while bodies once; the engine multiplies by trip count."""
    x = jnp.zeros((32, 64), jnp.float32)
    ws = jnp.zeros((24, 64, 64), jnp.float32)

    def f(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]

    cost, compiled = _cost(f, x, ws)
    expected = 24 * 2 * 32 * 64 * 64
    assert abs(cost.flops - expected) / expected < 0.05
    assert _xla_flops(compiled) < expected / 5  # body-once


def test_scan_matches_unrolled():
    x = jnp.zeros((16, 32), jnp.float32)
    ws = jnp.zeros((8, 32, 32), jnp.float32)

    def scanned(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]

    def unrolled(x, ws):
        for i in range(8):
            x = jnp.tanh(x @ ws[i])
        return x

    c1, _ = _cost(scanned, x, ws)
    c2, _ = _cost(unrolled, x, ws)
    assert abs(c1.flops - c2.flops) / c2.flops < 0.05


def test_collective_parsing():
    line = ("%all-reduce.1 = f32[64,1024]{1,0} all-reduce(%dot), channel_id=1, "
            "replica_groups=[4,4]<=[16], use_global_device_ids=true")
    table = {"dot": "f32[64,1024]{1,0}"}
    summary = hlotext.parse_collectives(
        "%dot = f32[64,1024]{1,0} parameter(0)\n" + line, 16)
    assert len(summary.ops) == 1
    op = summary.ops[0]
    assert op.kind == "all-reduce" and op.group_size == 4
    assert op.result_bytes == 64 * 1024 * 4
    # ring all-reduce wire bytes: 2*(g-1)/g * operand
    assert abs(op.wire_bytes - 2 * 3 / 4 * op.operand_bytes) < 1.0


def test_shape_bytes():
    assert hlotext.shape_bytes("f32[8,4]{1,0}") == 128
    assert hlotext.shape_bytes("bf16[10]") == 20
    assert hlotext.shape_bytes("(f32[2,2], s8[4])") == 20


def test_scope_bucketing():
    buckets = characterize.bucket_scopes({
        "jit(step)/lamb/mul": 10.0,
        "jit(step)/while/body/mlp/dot_general": 5.0,
        "jit(step)/while/attn_core/exp": 2.0,
        "unknown_thing": 1.0,
    })
    assert buckets["lamb"] == 10.0
    assert buckets["mlp"] == 5.0
    assert buckets["attn_bgemm"] == 2.0
    assert buckets["other"] == 1.0
