"""Tensor-parallel continuous serving: token-identical streams across
tp ∈ {1, 2, 4} (greedy + seeded-sampled, including forced-replay preemption
and a CoW tail), head-sharded pool/param specs, and engine validation.

Parity runs in a subprocess with 4 forced host devices (the pattern
``test_sharding.py`` established), so it executes in the plain tier-1 run
too — the ``tier1-multidevice`` CI job additionally runs this whole file
in-process under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
Parity uses fp32, like the cross-engine sampled-parity tests: bf16's
reassociated psum summation flips near-tied draws of the random-init smoke
model, which is rounding noise, not layout divergence.
"""
import subprocess
import sys
from pathlib import Path

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.models import build_model
from repro.parallel import sharding as sh

ROOT = Path(__file__).resolve().parents[1]


def _run_subprocess(body: str):
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'\n"
        "os.environ['JAX_PLATFORMS'] = 'cpu'\n" + body)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=ROOT, timeout=500,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    return r.stdout


# -------------------------------------------------------------------- parity ----

def test_tp_parity_greedy_sampled_and_preemption():
    """One subprocess covers the whole acceptance matrix: mixed
    greedy/sampled traffic token-identical across tp=1/2/4 and to the
    default (pre-TP) engine construction, then a starved pool forcing
    preemption replay (+ a shared prefix exercising the CoW tail copy)
    token-identical at tp=2 — with TP collective accounting non-zero only
    at tp > 1."""
    out = _run_subprocess(r"""
import dataclasses
import jax, numpy as np
from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import ContinuousEngine, Request
from repro.serving.sampling import SamplingParams

arch = dataclasses.replace(smoke_config("llama3.2-3b"), num_kv_heads=4,
                           dtype="float32", param_dtype="float32")
model = build_model(arch)
params = model.init(jax.random.key(0))
rng = np.random.default_rng(7)
prompts = [list(map(int, rng.integers(5, arch.vocab_size,
                                      int(rng.integers(4, 14)))))
           for _ in range(5)]
gens = [int(rng.integers(3, 9)) for _ in range(5)]
sps = [SamplingParams() if i % 2 == 0 else
       SamplingParams(temperature=0.8, top_k=12, top_p=0.9, seed=100 + i)
       for i in range(5)]
reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=gens[i],
                sampling=sps[i]) for i in range(5)]

def serve(**kw):
    eng = ContinuousEngine(model, params, num_slots=4, num_pages=64,
                           page_size=8, max_seq_len=64, **kw)
    res = eng.run(list(reqs))
    return eng, [res[i]["tokens"] for i in range(5)]

eng0, ref = serve()                       # default ctor == the pre-TP engine
assert any(len(t) for t in ref)
eng1, r1 = serve(tp=1)
assert r1 == ref and eng1.collective_bytes == 0
for tp in (2, 4):
    eng, toks = serve(tp=tp)
    assert toks == ref, (tp, toks, ref)
    assert eng.collective_bytes > 0
    stats = eng.tp_stats()
    assert stats["tp"] == tp and stats["per_device"]["kv_bytes"] > 0

# starved pool: forced-replay preemption + prefix cache + CoW tail, tp=2
rng = np.random.default_rng(37)
shared = list(map(int, rng.integers(5, arch.vocab_size, 10)))
pp = [shared + list(map(int, rng.integers(5, arch.vocab_size,
                                          int(rng.integers(2, 6)))))
      for _ in range(5)]
pg = [4, 16, 7, 12, 9]
ps = [SamplingParams(temperature=0.8, top_k=0 if i % 2 else 20, top_p=0.95,
                     seed=1000 + i) for i in range(5)]
preqs = [Request(uid=i, prompt=pp[i], max_new_tokens=pg[i], sampling=ps[i])
         for i in range(5)]

def starved(tp):
    eng = ContinuousEngine(model, params, num_slots=2, num_pages=10,
                           page_size=4, max_seq_len=40, tp=tp)
    res = eng.run(list(preqs))
    return eng, [res[i]["tokens"] for i in range(5)]

e1, s1 = starved(1)
e2, s2 = starved(2)
assert s1 == s2, (s1, s2)
assert e2.prefills > 5, "pool was not starved enough to preempt"
assert e2.cow_copies > 0, "shared tail never took the CoW path"
print("TP_PARITY_OK")
""")
    assert "TP_PARITY_OK" in out


def test_tp2_fused_vs_reference_sampler_parity():
    """The fused filter kernel and the sort-based reference must emit
    bit-identical sampled streams at tp=2 (logits are replicated post-psum,
    so the filter sees the same rows on every shard): fused tp=2 == ref
    tp=2 == fused tp=1."""
    out = _run_subprocess(r"""
import dataclasses
import jax, numpy as np
from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import ContinuousEngine, Request
from repro.serving.sampling import SamplingParams

arch = dataclasses.replace(smoke_config("llama3.2-3b"), num_kv_heads=4,
                           dtype="float32", param_dtype="float32")
model = build_model(arch)
params = model.init(jax.random.key(0))
rng = np.random.default_rng(61)
prompts = [list(map(int, rng.integers(5, arch.vocab_size,
                                      int(rng.integers(4, 12)))))
           for _ in range(4)]
gens = [int(rng.integers(4, 9)) for _ in range(4)]
sps = [SamplingParams(temperature=0.9, top_k=16 if i % 2 else 0,
                      top_p=0.85, seed=500 + i) for i in range(4)]
reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=gens[i],
                sampling=sps[i]) for i in range(4)]

def serve(tp, fused):
    eng = ContinuousEngine(model, params, num_slots=4, num_pages=64,
                           page_size=8, max_seq_len=64, tp=tp,
                           fused_sampling=fused)
    res = eng.run(list(reqs))
    return [res[i]["tokens"] for i in range(4)]

ref = serve(1, True)
assert serve(2, True) == ref, "fused tp=2 diverged from fused tp=1"
assert serve(2, False) == ref, "reference sampler tp=2 diverged"
print("TP2_SAMPLER_PARITY_OK")
""")
    assert "TP2_SAMPLER_PARITY_OK" in out


# --------------------------------------------------------- validation (1 dev) ---

def test_tp_rejects_indivisible_head_counts():
    arch = smoke_config("llama3.2-3b")        # 4 query heads, 2 kv heads
    model = build_model(arch)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    with pytest.raises(AssertionError, match="head"):
        # 3 divides neither head count — must fail before any mesh is built
        # (so the error names the arch, not the device count)
        from repro.serving import ContinuousEngine
        ContinuousEngine(model, params, tp=3)


def test_split_fused_qkv_is_exact():
    """Splitting the fused wqkv into wq/wk/wv must not change one projection
    output bit — it is the tp > 1 engine's precondition for head sharding."""
    import jax.numpy as jnp
    from repro.models.attention import qkv_project
    from repro.serving.engine import _split_fused_qkv

    arch = smoke_config("qwen2-vl-2b")        # fused qkv WITH biases
    model = build_model(arch)
    params = model.init(jax.random.key(3))
    split = _split_fused_qkv(params, arch)
    flat = jax.tree_util.tree_leaves_with_path(split)
    names = {kp[-1].key for kp, _ in flat if hasattr(kp[-1], "key")}
    assert "wqkv" not in names and {"wq", "wk", "wv"} <= names

    def first_attn(tree):
        blocks = tree["blocks"]
        blk = blocks["period_0"] if "period_0" in blocks else blocks
        return blk["layer_0"]["attn"]

    fused, sep = first_attn(params), first_attn(split)
    if fused["wqkv"].ndim == 3:                # scanned stack: take period 0
        fused = jax.tree.map(lambda a: a[0], fused)
        sep = jax.tree.map(lambda a: a[0], sep)
    x = jax.random.normal(jax.random.key(4), (2, 3, arch.d_model),
                          jnp.float32)
    for a, b in zip(qkv_project(arch, fused, x), qkv_project(arch, sep, x)):
        assert jnp.array_equal(a, b)


def test_serving_param_pspecs_layout():
    """The TP serving spec table: projections sharded Megatron-style,
    everything that must stay replicated (embedding, lm head, norms,
    row-parallel biases) replicated — the invariant that makes logits and
    sampler draws identical on every shard."""
    from repro.serving.engine import _split_fused_qkv

    arch = smoke_config("qwen2-vl-2b")
    model = build_model(arch)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    with pytest.raises(ValueError, match="fused"):
        sh.serving_param_pspecs(params)        # fused wqkv must be rejected
    split = jax.eval_shape(lambda: _split_fused_qkv(
        model.init(jax.random.key(0)), arch))
    specs = sh.serving_param_pspecs(split)

    seen = {}
    for kp, spec in jax.tree_util.tree_leaves_with_path(
            specs, is_leaf=lambda s: isinstance(s, P)):
        name = kp[-1].key
        seen.setdefault(name, spec)
    assert seen["wq"][-1] == "model" and seen["wv"][-1] == "model"
    assert seen["wo"][-2] == "model" and seen["wo"][-1] is None
    assert seen["w1"][-1] == "model" and seen["w2"][-2] == "model"
    assert seen["bq"][-1] == "model"
    # replicated: anything whose value feeds a post-psum (or logits) path
    for name in ("embedding", "scale", "bo", "b2"):
        if name in seen:
            assert all(a is None for a in seen[name]), (name, seen[name])


def test_paged_pool_pspecs_shard_head_axis():
    import jax.numpy as jnp
    from repro.models import transformer as tf

    for name in ("llama3.2-3b", "internlm2-1.8b"):
        arch = smoke_config(name)
        pools = jax.eval_shape(
            lambda a=arch: tf.init_paged_caches(a, 8, 4, jnp.float32))
        specs = sh.paged_pool_pspecs(pools)
        for spec, leaf in zip(
                jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P)),
                jax.tree.leaves(pools)):
            assert spec[leaf.ndim - 2] == "model"       # the Hkv axis
            assert all(a is None for i, a in enumerate(spec)
                       if i != leaf.ndim - 2)
