"""Serving path: prefill+decode logits must match the training forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import build_model

ARCHS = ["llama3.2-3b", "mamba2-1.3b", "jamba-v0.1-52b", "deepseek-moe-16b",
         "whisper-base", "qwen2-vl-2b"]


@pytest.mark.parametrize(
    "name", [pytest.param(n, marks=pytest.mark.slow)
             if n in ("jamba-v0.1-52b", "whisper-base") else n
             for n in ARCHS])
def test_prefill_decode_match_forward(name):
    arch = smoke_config(name)
    if arch.moe is not None:  # avoid capacity-drop divergence (tested in moe)
        arch = dataclasses.replace(
            arch, moe=dataclasses.replace(arch.moe, capacity_factor=8.0))
    model = build_model(arch)
    p = model.init(jax.random.key(1))
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.key(2), (b, s), 5, arch.vocab_size)
    batch = {"tokens": tokens, "targets": tokens,
             "loss_mask": jnp.ones((b, s), jnp.bfloat16)}
    if arch.family == "encdec":
        batch["frontend_embeddings"] = jax.random.normal(
            jax.random.key(3), (b, arch.enc_seq_len, arch.d_model)
        ).astype(jnp.bfloat16)
    if arch.frontend == "vision_stub":
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32)
    full, _ = jax.jit(model.forward)(p, batch)

    caches = model.init_caches(None, b, 64)
    pb = {"tokens": tokens[:, :s - 1]}
    if arch.family == "encdec":
        pb["frontend_embeddings"] = batch["frontend_embeddings"]
    if arch.frontend == "vision_stub":
        pb["mrope_positions"] = batch["mrope_positions"][:, :, :s - 1]
    pre, caches = jax.jit(model.prefill)(p, caches, pb)
    db = {"tokens": tokens[:, s - 1:s],
          "positions": jnp.full((b,), s - 1, jnp.int32)}
    if arch.frontend == "vision_stub":
        db["mrope_positions"] = batch["mrope_positions"][:, :, s - 1:s]
    dec, _ = jax.jit(model.decode_step)(p, caches, db)

    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert float(jnp.max(jnp.abs(pre[:, 0] - full[:, s - 2]))) < 0.05 * scale
    assert float(jnp.max(jnp.abs(dec[:, 0] - full[:, s - 1]))) < 0.05 * scale
