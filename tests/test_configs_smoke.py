"""Per-arch smoke tests: reduced config, one forward/train step, shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, REGISTRY, get_config, smoke_config
from repro.models import build_model
from repro.models.layers import pad_vocab


def _batch(arch, b=2, s=32):
    tokens = jax.random.randint(jax.random.key(2), (b, s), 5, arch.vocab_size)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1),
             "loss_mask": jnp.ones((b, s), jnp.bfloat16)}
    if arch.family == "encdec":
        batch["frontend_embeddings"] = jnp.ones(
            (b, arch.enc_seq_len, arch.d_model), jnp.bfloat16)
    if arch.frontend == "vision_stub":
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32)
    return batch


# the scan/remat train-step compiles take tens of seconds for the deep or
# multi-component archs; keep a fast representative subset in tier-1
_HEAVY = {"jamba-v0.1-52b", "bert-large", "llama4-maverick-400b-a17b",
          "whisper-base"}


@pytest.mark.parametrize(
    "name", [pytest.param(n, marks=pytest.mark.slow) if n in _HEAVY else n
             for n in sorted(REGISTRY)])
def test_forward_and_train_step(name):
    arch = smoke_config(name)
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    batch = _batch(arch)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 32, pad_vocab(arch.vocab_size))
    assert bool(jnp.isfinite(logits).all()), name
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0 and jnp.isfinite(gnorm)


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_full_config_matches_assignment(name):
    full = get_config(name)
    # spot-check the assignment table is encoded exactly
    expected = {
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    }[name]
    got = (full.num_layers, full.d_model, full.num_heads, full.num_kv_heads,
           full.d_ff, full.vocab_size)
    assert got == expected, (name, got, expected)


def test_param_counts_match_nameplates():
    tol = {"mistral-large-123b": (110e9, 130e9),
           "command-r-35b": (28e9, 38e9),
           "llama4-maverick-400b-a17b": (380e9, 420e9),
           "jamba-v0.1-52b": (48e9, 56e9),
           "deepseek-moe-16b": (15e9, 18e9),
           "mamba2-1.3b": (1.2e9, 1.5e9),
           "bert-large": (0.3e9, 0.36e9)}
    for name, (lo, hi) in tol.items():
        p = get_config(name).param_count()
        assert lo <= p <= hi, (name, p)


def test_moe_active_params():
    c = get_config("deepseek-moe-16b")
    assert c.param_count(active_only=True) < 0.25 * c.param_count()
