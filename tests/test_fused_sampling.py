"""Fused sort-free sampling filter vs its sort-based oracle.

Three layers of parity, all bit-exact:

1. kernel level — the streaming jnp bisection path, the Pallas kernel in
   interpret mode, and the one-sort reference must produce identical masked
   logits on adversarial inputs (ties at the k-th value, ``top_p = 1.0``,
   ``top_k >= V``, pre-masked ``-inf`` entries, all-``-inf`` rows,
   float32-tight nucleus boundaries, signed zeros): hypothesis sweep plus a
   pinned no-hypothesis instance per edge case.
2. sampler level — ``sample_tokens(..., fused=True)`` vs ``fused=False``
   draw identical tokens, and both agree with the retired twin-sort
   implementation (kept verbatim below as ``_legacy_filter``) away from its
   float32 cumsum boundaries.
3. engine level — fused and reference continuous engines serve identical
   sampled token streams, and each compiles its own named filter variant.

Conventions mirror ``test_sampling.py`` (optional hypothesis with a pinned
fallback, the fp32 smoke llama fixture, ``_mixed_requests``-style traffic).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    given = settings = st = None

from repro.configs import smoke_config
from repro.kernels.fused_sampling import kernel, ops, ref
from repro.models import build_model
from repro.serving import (ContinuousEngine, Request, SamplingParams,
                           sample_tokens)

V = 512          # smoke vocab


# ----------------------------------------------------------- case generators --

def _case(seed: int, *, ties=False, neg_inf=False, dead_row=False,
          signed_zeros=False, scale=1.0, rows=4, vocab=V):
    """One adversarial (logits, top_k, top_p) instance."""
    rng = np.random.default_rng(seed)
    lg = rng.normal(size=(rows, vocab)).astype(np.float32) * scale
    if ties:
        lg = np.round(lg * 2) / 2           # massive duplication, ties at kth
    if neg_inf:
        lg[rng.random(size=lg.shape) < 0.3] = -np.inf
    if dead_row:
        lg[0, :] = -np.inf
    if signed_zeros:
        lg[1, :8] = 0.0
        lg[1, 8:16] = -0.0
    top_k = rng.integers(-1, vocab + 100, size=rows).astype(np.int32)
    top_p = rng.choice([0.3, 0.9, 0.95, 0.999, 1.0],
                       size=rows).astype(np.float32)
    return jnp.asarray(lg), jnp.asarray(top_k), jnp.asarray(top_p)


def _assert_threeway(lg, top_k, top_p):
    """ref oracle == streaming jnp path == Pallas kernel (interpret), bit
    for bit (NaN patterns compared as equal — thresholds may round-trip a
    non-signalling pattern, the masks never differ)."""
    a = np.asarray(ref.filter_logits_ref(lg, top_k, top_p))
    b = np.asarray(ops._filter_logits_jnp(lg, top_k, top_p))
    c = np.asarray(kernel.filter_logits(lg, top_k, top_p, interpret=True))
    assert np.array_equal(a, b, equal_nan=True), "jnp path diverged from ref"
    assert np.array_equal(a, c, equal_nan=True), "pallas kernel diverged"
    return a


# --------------------------------------------------- hypothesis parity sweep --

if st is not None:
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 100_000),
        ties=st.booleans(),
        neg_inf=st.booleans(),
        dead_row=st.booleans(),
        signed_zeros=st.booleans(),
        scale=st.sampled_from([1.0, 5.0, 30.0]),
    )
    def test_filter_parity_property_sweep(seed, ties, neg_inf, dead_row,
                                          signed_zeros, scale):
        _assert_threeway(*_case(seed, ties=ties, neg_inf=neg_inf,
                                dead_row=dead_row,
                                signed_zeros=signed_zeros, scale=scale))
else:
    def test_filter_parity_property_sweep():
        pytest.importorskip("hypothesis")


# -------------------------------------------- pinned no-hypothesis instances --

def test_parity_ties_at_kth_value():
    """Quantized logits put duplicates exactly at the k-th largest value;
    the filter is a value threshold, so every tie is kept — identically in
    all three implementations."""
    lg, _, _ = _case(11, ties=True)
    top_k = jnp.full((4,), 7, jnp.int32)
    top_p = jnp.ones((4,), jnp.float32)
    out = _assert_threeway(lg, top_k, top_p)
    kept = (out > -np.inf).sum(axis=-1)
    lg_np = np.asarray(lg)
    for r in range(4):
        kth = np.sort(lg_np[r])[::-1][6]
        assert kept[r] == (lg_np[r] >= kth).sum()     # ties included
        assert kept[r] >= 7


def test_parity_top_p_disabled_keeps_topk_support():
    """top_p = 1.0 must be an exact no-op on the top-k-masked row (the
    historical sampler guaranteed this explicitly; the threshold form pins
    the threshold at -inf)."""
    lg, _, _ = _case(12)
    top_k = jnp.asarray([5, 0, 513, 1], jnp.int32)
    top_p = jnp.ones((4,), jnp.float32)
    out = _assert_threeway(lg, top_k, top_p)
    kept = (out > -np.inf).sum(axis=-1)
    assert list(kept) == [5, V, V, 1]


def test_parity_top_k_at_least_vocab_is_noop():
    lg, _, _ = _case(13)
    for k in (V, V + 1, 10_000, 0, -3):
        out = _assert_threeway(lg, jnp.full((4,), k, jnp.int32),
                               jnp.ones((4,), jnp.float32))
        assert np.array_equal(out, np.asarray(lg))


def test_parity_premasked_neg_inf_rows():
    lg, tk, tp = _case(14, neg_inf=True)
    _assert_threeway(lg, tk, tp)


def test_parity_fully_masked_row_passes_through():
    """An all--inf row has zero mass: no threshold can bind, the row comes
    back unchanged (and the categorical draw downstream is identical for
    both implementations because the masked logits are)."""
    lg, _, _ = _case(15, dead_row=True)
    out = _assert_threeway(lg, jnp.full((4,), 10, jnp.int32),
                           jnp.full((4,), 0.5, jnp.float32))
    assert (out[0] == -np.inf).all()


def test_parity_signed_zero_boundary():
    """-0.0 and +0.0 straddle the bit-key order but compare equal as
    floats; whatever threshold the bisections land on, the masks must
    agree."""
    lg, _, _ = _case(16, signed_zeros=True, scale=0.001)
    for tp in (0.3, 0.5, 0.9, 1.0):
        _assert_threeway(lg, jnp.full((4,), 0, jnp.int32),
                         jnp.full((4,), tp, jnp.float32))


def test_parity_float32_tight_nucleus_boundary():
    """Geometric rows where the cumulative mass hits top_p exactly (0.5 +
    0.25 + ... with top_p on the partial sums): the classic spot where two
    float32 cumsum orders disagree by one token. The shared strict-greater-
    mass predicate makes all three implementations cut identically."""
    lg = np.full((4, V), -np.inf, np.float32)
    lg[:, :16] = np.log(2.0) * -np.arange(16)       # probs 1/2^i (unnorm)
    lg = jnp.asarray(lg)
    for tp in (0.5, 0.75, 0.875, 0.8749999, 0.8750001):
        _assert_threeway(lg, jnp.zeros((4,), jnp.int32),
                         jnp.full((4,), tp, jnp.float32))


def test_parity_pinned_smoke_without_hypothesis():
    """One pinned instance of the property sweep (runs without hypothesis),
    plus the underflow-tail scale the sweep samples."""
    _assert_threeway(*_case(4321, ties=True, neg_inf=True, scale=30.0))
    _assert_threeway(*_case(1234, dead_row=True, signed_zeros=True))


# ------------------------------------------- legacy twin-sort sampler parity --

def _legacy_filter(lg, top_k, top_p):
    """The retired twin-sort filter, verbatim from the old
    ``serving.sampling.sample_tokens`` — the semantics the fused filter
    replaced (top-k value threshold + float32-cumsum nucleus)."""
    lg = jnp.asarray(lg, jnp.float32)
    vocab = lg.shape[-1]
    k = jnp.where(top_k <= 0, vocab, jnp.minimum(top_k, vocab))
    kth = jnp.take_along_axis(jnp.sort(lg, axis=-1), (vocab - k)[:, None],
                              axis=-1)
    lg = jnp.where(lg < kth, -jnp.inf, lg)
    desc = jnp.sort(lg, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    tp = top_p.astype(jnp.float32)[:, None]
    keep = ((cum - probs) < tp) | (tp >= 1.0)
    cutoff = jnp.maximum(jnp.sum(keep, axis=-1) - 1, 0)
    thresh = jnp.take_along_axis(desc, cutoff[:, None], axis=-1)
    return jnp.where(lg < thresh, -jnp.inf, lg)


def test_fused_matches_legacy_sampler_on_generic_logits():
    """Away from float32 nucleus-boundary rounding (generic continuous
    logits — pinned seeds, verified clear of the boundary) the fused filter
    keeps exactly the support the twin-sort implementation kept. This pins
    the redefinition of the cut from "f32 cumsum rank" to "strict-greater
    mass" as a rounding-level change, not a semantic one."""
    for seed in (0, 1, 2, 3, 4, 5):
        lg, tk, _ = _case(seed)
        tp = jnp.full((4,), 0.9, jnp.float32)
        legacy = np.asarray(_legacy_filter(lg, tk, tp))
        fused = np.asarray(ops.filter_logits(lg, tk, tp))
        assert np.array_equal(legacy, fused), f"seed {seed}"


# ---------------------------------------------------- sample_tokens bit parity -

def _arrs(rows, seed=0, pos=0, temp=1.0, top_k=0, top_p=1.0):
    def vec(v, dt):
        a = np.asarray(v, dt)
        return jnp.asarray(np.broadcast_to(a, (rows,)))
    return (vec(seed, np.uint32), vec(pos, np.int32),
            vec(temp, np.float32), vec(top_k, np.int32),
            vec(top_p, np.float32))


def test_sample_tokens_fused_flag_is_token_invisible():
    """Identical draws from the fused and reference filters across seeds,
    positions, and filter settings — the flag changes speed, never tokens."""
    rng = np.random.default_rng(8)
    logits = jnp.asarray(rng.normal(size=(6, V)).astype(np.float32))
    for pos in range(12):
        for tk, tp in ((40, 0.95), (8, 1.0), (0, 0.7), (1, 0.5)):
            args = _arrs(6, seed=range(6), pos=pos, temp=0.8, top_k=tk,
                         top_p=tp)
            a = sample_tokens(logits, *args, fused=True)
            b = sample_tokens(logits, *args, fused=False)
            assert (np.asarray(a) == np.asarray(b)).all(), (pos, tk, tp)


def test_sample_tokens_fused_temp_zero_is_bitwise_argmax():
    rng = np.random.default_rng(9)
    logits = jnp.asarray(rng.normal(size=(5, V)).astype(np.float32))
    for fused in (True, False):
        toks = sample_tokens(logits, *_arrs(5, temp=0.0, top_k=40,
                                            top_p=0.9), fused=fused)
        assert (np.asarray(toks) == np.argmax(np.asarray(logits), -1)).all()


def test_sample_tokens_fused_restricts_support():
    """The fused path enforces the filters it claims to: top-k draws stay in
    the top-k set, nucleus draws in the nucleus."""
    rng = np.random.default_rng(10)
    logits_np = rng.normal(size=(1, 64)).astype(np.float32)
    logits = jnp.asarray(logits_np)
    top = set(np.argsort(logits_np[0])[-5:])
    drawn = set()
    for pos in range(40):
        toks = sample_tokens(logits, *_arrs(1, seed=9, pos=pos, temp=1.5,
                                            top_k=5), fused=True)
        drawn.add(int(toks[0]))
    assert drawn <= top and len(drawn) > 1


# ------------------------------------------------------- engine-level parity --

@pytest.fixture(scope="module")
def fp32_llama():
    arch = smoke_config("llama3.2-3b")
    arch = dataclasses.replace(arch, dtype="float32", param_dtype="float32")
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    return arch, model, params


def _sampled_requests(arch, rng, n=4):
    reqs = []
    for i in range(n):
        prompt = list(map(int, rng.integers(5, arch.vocab_size,
                                            int(rng.integers(4, 14)))))
        sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.95,
                            seed=int(rng.integers(2 ** 31)))
        reqs.append(Request(uid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(4, 9)),
                            sampling=sp))
    return reqs


def test_fused_and_reference_engines_serve_identical_streams(fp32_llama):
    arch, model, params = fp32_llama
    rng = np.random.default_rng(51)
    reqs = _sampled_requests(arch, rng)
    kw = dict(num_slots=4, num_pages=48, page_size=8, max_seq_len=64,
              prefix_cache=False)
    tokens = {}
    for fused in (True, False):
        engine = ContinuousEngine(model, params, fused_sampling=fused, **kw)
        res = engine.run([dataclasses.replace(r) for r in reqs])
        tokens[fused] = [res[i]["tokens"] for i in range(len(reqs))]
        # the engine compiled the filter variant it was asked for, and the
        # variant key names the implementation
        fd = engine.fused_decode
        assert ("decode", True, True, fused, fd) in engine._jit_cache
        assert ("decode", True, True, not fused, fd) not in engine._jit_cache
    assert tokens[True] == tokens[False], \
        "fused filter diverged from the sort-based reference in serving"


def test_env_toggle_selects_reference_filter(fp32_llama, monkeypatch):
    arch, model, params = fp32_llama
    monkeypatch.setenv("REPRO_FUSED_SAMPLING", "0")
    engine = ContinuousEngine(model, params, num_slots=2, num_pages=16,
                              page_size=8, max_seq_len=32)
    assert engine.fused_sampling is False
    monkeypatch.setenv("REPRO_FUSED_SAMPLING", "1")
    engine = ContinuousEngine(model, params, num_slots=2, num_pages=16,
                              page_size=8, max_seq_len=32)
    assert engine.fused_sampling is True
