"""Data pipeline: determinism, host sharding, MLM semantics, resumability."""
import numpy as np

from repro.data import DataConfig, SyntheticPipeline


def test_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    p1, p2 = SyntheticPipeline(cfg), SyntheticPipeline(cfg)
    b1, b2 = p1.batch(17), p2.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch(17)["tokens"], p1.batch(18)["tokens"])


def test_host_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    full = SyntheticPipeline(cfg).batch(3)["tokens"]
    shards = [SyntheticPipeline(
        DataConfig(vocab_size=1000, seq_len=16, global_batch=8,
                   host_id=h, num_hosts=2)).batch(3)["tokens"]
        for h in range(2)]
    assert shards[0].shape == (4, 16)
    assert not np.array_equal(shards[0], shards[1])


def test_mlm_masking_semantics():
    cfg = DataConfig(vocab_size=1000, seq_len=128, global_batch=4,
                     objective="mlm")
    b = SyntheticPipeline(cfg).batch(0)
    sel = b["loss_mask"] > 0
    rate = sel.mean()
    assert 0.08 < rate < 0.22
    # at masked positions targets keep the original token; most inputs become MASK
    masked_inputs = b["tokens"][sel]
    assert (masked_inputs == 4).mean() > 0.6
    # unmasked positions are untouched
    assert np.array_equal(b["tokens"][~sel], b["targets"][~sel])


def test_causal_targets_shifted():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=2)
    b = SyntheticPipeline(cfg).batch(0)
    np.testing.assert_array_equal(b["targets"][:, :-1], b["tokens"][:, 1:])
    assert (b["loss_mask"][:, -1] == 0).all()


def test_no_seed_collisions_across_steps_and_hosts():
    """The old ``seed*7 + step*13 + host_id`` mix collided across (step, host)
    — e.g. (step=1, host=0) vs (step=0, host=13) drew identical MLM masks.
    Every (step, host) pair must get a distinct masking stream."""
    def mask_for(step, host):
        cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=28,
                         objective="mlm", host_id=host, num_hosts=14)
        return SyntheticPipeline(cfg).batch(step)["loss_mask"]

    # the exact historical collision pair
    assert not np.array_equal(mask_for(1, 0), mask_for(0, 13))
    # and a broader sweep: all (step, host) mask patterns pairwise distinct
    seen = {}
    for step in range(4):
        for host in range(14):
            key = mask_for(step, host).tobytes()
            assert key not in seen, f"collision: {(step, host)} vs {seen[key]}"
            seen[key] = (step, host)


def test_resume_determinism_mid_stream():
    """A pipeline resumed at step k (fresh process, fresh object) must emit
    byte-identical batches to the original run — restart safety."""
    cfg = DataConfig(vocab_size=500, seq_len=32, global_batch=4,
                     objective="mlm", seed=77)
    orig = [SyntheticPipeline(cfg).batch(s) for s in range(6)]
    resumed = SyntheticPipeline(cfg)
    for s in range(3, 6):
        b = resumed.batch(s)
        for k in ("tokens", "targets", "loss_mask"):
            np.testing.assert_array_equal(b[k], orig[s][k])


def test_iterator_prefetch():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    pipe = SyntheticPipeline(cfg)
    it = pipe.iterator(start_step=5, prefetch=2)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], pipe.batch(5)["tokens"])
