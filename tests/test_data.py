"""Data pipeline: determinism, host sharding, MLM semantics, resumability."""
import numpy as np

from repro.data import DataConfig, SyntheticPipeline


def test_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    p1, p2 = SyntheticPipeline(cfg), SyntheticPipeline(cfg)
    b1, b2 = p1.batch(17), p2.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch(17)["tokens"], p1.batch(18)["tokens"])


def test_host_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    full = SyntheticPipeline(cfg).batch(3)["tokens"]
    shards = [SyntheticPipeline(
        DataConfig(vocab_size=1000, seq_len=16, global_batch=8,
                   host_id=h, num_hosts=2)).batch(3)["tokens"]
        for h in range(2)]
    assert shards[0].shape == (4, 16)
    assert not np.array_equal(shards[0], shards[1])


def test_mlm_masking_semantics():
    cfg = DataConfig(vocab_size=1000, seq_len=128, global_batch=4,
                     objective="mlm")
    b = SyntheticPipeline(cfg).batch(0)
    sel = b["loss_mask"] > 0
    rate = sel.mean()
    assert 0.08 < rate < 0.22
    # at masked positions targets keep the original token; most inputs become MASK
    masked_inputs = b["tokens"][sel]
    assert (masked_inputs == 4).mean() > 0.6
    # unmasked positions are untouched
    assert np.array_equal(b["tokens"][~sel], b["targets"][~sel])


def test_causal_targets_shifted():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=2)
    b = SyntheticPipeline(cfg).batch(0)
    np.testing.assert_array_equal(b["targets"][:, :-1], b["tokens"][:, 1:])
    assert (b["loss_mask"][:, -1] == 0).all()


def test_iterator_prefetch():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    pipe = SyntheticPipeline(cfg)
    it = pipe.iterator(start_step=5, prefetch=2)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], pipe.batch(5)["tokens"])
