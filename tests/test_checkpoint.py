"""Checkpoint manager: roundtrip, keep-N GC, atomic commit, async, kill/resume."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(scale=1.0):
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4) * scale,
                       "b": jnp.ones((4,)) * scale},
            "opt": {"step": jnp.array(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(10, _state(), extra={"data_step": 10})
    out = mgr.restore()
    assert out["step"] == 10 and out["extra"]["data_step"] == 10
    np.testing.assert_array_equal(out["state"]["params"]["w"],
                                  np.asarray(_state()["params"]["w"]))


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s))
    steps = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert len(steps) == 2 and steps[-1].endswith("4".zfill(10))


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(5, _state())
    mgr.wait()
    assert mgr.latest_step() == 5


def test_no_partial_checkpoints(tmp_path):
    """tmp dirs never count as checkpoints (atomic rename commit)."""
    mgr = CheckpointManager(tmp_path)
    (Path(tmp_path) / "tmp.99").mkdir()
    assert mgr.latest_step() is None


@pytest.mark.slow
def test_kill_and_resume_continuity(tmp_path):
    """Fault tolerance end-to-end: train 40 steps with ckpt_every=20, kill,
    restart — the resumed run continues from step 40's checkpoint and the
    loss trajectory stays finite/decreasing-ish."""
    script = (
        "import sys; sys.argv=['t']; "
        "from repro.launch.train import main; "
        "main(['--arch','bert-large','--smoke','--batch','4','--seq','32',"
        f"'--steps','{{steps}}','--ckpt-dir','{tmp_path}',"
        "'--ckpt-every','20'])"
    )
    env = {"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu", "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env})
    r1 = subprocess.run([sys.executable, "-c", script.format(steps=40)],
                        capture_output=True, text=True, env=env,
                        cwd=Path(__file__).resolve().parents[1], timeout=400)
    assert r1.returncode == 0, r1.stderr[-2000:]
    mgr = CheckpointManager(tmp_path)
    assert mgr.latest_step() == 40
    # "crash" happened here; restart with a higher step budget
    r2 = subprocess.run([sys.executable, "-c", script.format(steps=60)],
                        capture_output=True, text=True, env=env,
                        cwd=Path(__file__).resolve().parents[1], timeout=400)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 40" in r2.stdout
    assert mgr.latest_step() == 60
