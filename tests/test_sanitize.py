"""Runtime sanitizer: every invariant has a seeded violation that must be
detected, plus the do-no-harm contract (sanitize mode changes no tokens).

The injection tests corrupt the engine mid-run the way a real bug would —
a ``free()`` that drops a hold on the floor, a ``finish()`` that loses the
slot — and assert the sanitizer raises at the next request boundary,
naming the page/slot. Unit tests then pin each invariant check in
isolation against hand-built corrupt states.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitize import (SanitizerError, check_allocator,
                                     check_engine, check_prefix, check_slots)
from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import ContinuousEngine, Request
from repro.serving.kv_cache import PageAllocator
from repro.serving.sampling import SamplingParams


@pytest.fixture(scope="module")
def dense():
    arch = smoke_config("llama3.2-3b")
    model = build_model(arch)
    return arch, model, model.init(jax.random.key(0))


def _requests(arch, n=4, gen=5, seed=7):
    rng = np.random.default_rng(seed)
    shared = list(map(int, rng.integers(5, arch.vocab_size, 9)))
    reqs = []
    for i in range(n):
        prompt = (shared + list(map(int, rng.integers(5, arch.vocab_size, 3)))
                  if i % 2 == 0 else
                  list(map(int, rng.integers(
                      5, arch.vocab_size, int(rng.integers(4, 12))))))
        sp = SamplingParams() if i % 2 == 0 else SamplingParams(
            temperature=0.8, top_k=10, seed=50 + i)
        reqs.append(Request(uid=i, prompt=prompt, max_new_tokens=gen,
                            sampling=sp))
    return reqs


def _engine(model, params, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("num_pages", 24)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_seq_len", 48)
    return ContinuousEngine(model, params, **kw)


# ----------------------------------------------------------- do no harm -----

def test_sanitize_clean_run_is_token_identical(dense):
    arch, model, params = dense
    reqs = _requests(arch)
    plain = _engine(model, params).run(_requests(arch))
    checked = _engine(model, params, sanitize=True).run(reqs)
    for r in reqs:
        assert checked[r.uid]["tokens"] == plain[r.uid]["tokens"]
        assert len(checked[r.uid]["tokens"]) == 5


def test_sanitize_env_opt_in(dense, monkeypatch):
    arch, model, params = dense
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert _engine(model, params).sanitize
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert not _engine(model, params).sanitize
    # explicit argument beats the environment
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert not _engine(model, params, sanitize=False).sanitize


# ---------------------------------------------------- injected violations ---

def test_injected_refcount_leak_detected(dense):
    """A free() that silently drops one hold — the classic leak. The
    sanitizer must catch it at the next request completion."""
    arch, model, params = dense
    eng = _engine(model, params, sanitize=True)
    allocator = eng.scheduler.allocator
    orig_free = allocator.free
    leaked = []

    def leaky_free(pages):
        if pages and not leaked:
            leaked.append(pages[0])     # this page's hold is never dropped
            pages = pages[1:]
        orig_free(pages)

    allocator.free = leaky_free
    with pytest.raises(SanitizerError, match="refcount|conservation"):
        eng.run(_requests(arch))
    assert leaked


def test_injected_slot_desync_detected(dense):
    """A finish() that forgets to return the slot to the free list — the
    slot vanishes from both running and free."""
    arch, model, params = dense
    eng = _engine(model, params, sanitize=True)
    sched = eng.scheduler
    orig_finish = sched.finish
    broken = []

    def bad_finish(seq):
        orig_finish(seq)
        if not broken:
            broken.append(sched._free_slots.pop())   # lose the slot
    sched.finish = bad_finish
    with pytest.raises(SanitizerError, match="neither running nor free"):
        eng.run(_requests(arch))
    assert broken


def test_injected_nan_params_detected(dense):
    """NaN weights make NaN logits: the device-side probe must trip on the
    first final prefill chunk. Without the sanitizer the argmax of NaN
    logits silently emits token 0 — exactly the failure mode the probe
    exists for."""
    arch, model, params = dense
    nan_params = jax.tree.map(lambda a: (a * jnp.nan).astype(a.dtype)
                              if jnp.issubdtype(a.dtype, jnp.floating) else a,
                              params)
    reqs = _requests(arch, n=2)
    silent = _engine(model, nan_params).run([
        Request(uid=r.uid, prompt=r.prompt, max_new_tokens=r.max_new_tokens)
        for r in reqs])
    assert all(len(r["tokens"]) > 0 for r in silent.values())  # no error!
    with pytest.raises(SanitizerError, match="finite"):
        _engine(model, nan_params, sanitize=True).run(reqs)


# ----------------------------------------------------- per-invariant units --

def test_allocator_conservation_leaked_page():
    a = PageAllocator(8)
    pages = a.alloc(3)
    check_allocator(a)
    del a._refs[pages[1]]               # page now in neither free nor refs
    with pytest.raises(SanitizerError, match="leak"):
        check_allocator(a)


def test_allocator_conservation_double_tracking():
    a = PageAllocator(8)
    pages = a.alloc(2)
    a._free.append(pages[0])            # free while still refcounted
    with pytest.raises(SanitizerError, match="both free and refcounted"):
        check_allocator(a)


def test_allocator_conservation_duplicate_free():
    a = PageAllocator(8)
    a._free.append(a._free[0])
    with pytest.raises(SanitizerError, match="duplicate"):
        check_allocator(a)


def test_refcount_accounting_detects_unbacked_ref(dense):
    arch, model, params = dense
    eng = _engine(model, params, sanitize=True)
    res = eng.run(_requests(arch, n=2))
    assert len(res) == 2
    check_engine(eng)                   # clean after a full trace
    page = eng.scheduler.allocator.alloc(1)[0]   # ref'd, no visible holder
    with pytest.raises(SanitizerError, match="no visible holder"):
        check_engine(eng)
    eng.scheduler.allocator.free([page])
    check_engine(eng)


def test_slot_consistency_free_slot_with_pages(dense):
    arch, model, params = dense
    eng = _engine(model, params)
    eng.run(_requests(arch, n=2))
    slot = eng.scheduler._free_slots[0]
    eng.scheduler.cache.seq_lens[slot] = 3
    with pytest.raises(SanitizerError, match="seq_len"):
        check_slots(eng)


def test_slot_consistency_seq_len_drift(dense):
    """A seq_len that disagrees with the sequence's lifecycle stage — the
    shape-level desync that silently mis-masks attention."""
    arch, model, params = dense
    eng = _engine(model, params)
    caught = []
    orig = eng.scheduler.finish

    def tamper(seq):
        other = [s for s in eng.scheduler.running if s != seq.slot]
        if not caught and other:
            eng.scheduler.cache.seq_lens[other[0]] += 2
            with pytest.raises(SanitizerError, match="seq_len"):
                check_slots(eng)
            eng.scheduler.cache.seq_lens[other[0]] -= 2
            caught.append(other[0])
        orig(seq)

    eng.scheduler.finish = tamper
    eng.run(_requests(arch))
    assert caught


def test_prefix_holds_drift_detected(dense):
    arch, model, params = dense
    eng = _engine(model, params)
    eng.run(_requests(arch))
    prefix = eng.scheduler.prefix
    assert prefix is not None and prefix._holds, "trace cached nothing"
    check_prefix(prefix, eng.scheduler.allocator)
    page = next(iter(prefix._holds))
    prefix._holds[page] += 1            # incremental map drifts from entries
    with pytest.raises(SanitizerError, match="drifted"):
        check_prefix(prefix, eng.scheduler.allocator)


def test_prefix_children_drift_detected(dense):
    arch, model, params = dense
    eng = _engine(model, params)
    eng.run(_requests(arch))
    prefix = eng.scheduler.prefix
    assert prefix._full, "trace cached no full pages"
    entry = next(iter(prefix._full.values()))
    entry.children += 1
    with pytest.raises(SanitizerError, match="children"):
        check_prefix(prefix, eng.scheduler.allocator)
