"""Prefix caching + chunked prefill: index semantics, CoW, page dedup, and
exact greedy parity across {static, continuous, continuous+prefix-cache}."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    given = settings = st = None

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import (ContinuousEngine, PageAllocator, PrefixIndex,
                           Request)


# ---------------------------------------------------------------- prefix index ---

def _index(num_pages=32, page_size=4):
    alloc = PageAllocator(num_pages)
    return alloc, PrefixIndex(alloc, page_size)


def test_index_matches_longest_full_page_chain():
    alloc, idx = _index()
    toks = list(range(100, 110))               # 2 full pages + 2-token tail
    pages = alloc.alloc(3)
    idx.insert(toks, pages)
    # exact prefix: both full pages + the partial tail
    full, tail = idx.match(toks + [1, 2])
    assert full == pages[:2]
    assert tail == (pages[2], 2)
    # diverges inside page 2: only page 1 matches; no tail under that node
    full, tail = idx.match(toks[:4] + [999] * 6)
    assert full == pages[:1] and tail is None
    # diverges at token 0: nothing
    full, tail = idx.match([999] + toks[1:])
    assert full == [] and tail is None


def test_index_partial_tail_lcp():
    alloc, idx = _index()
    toks = list(range(100, 107))               # 1 full page + 3-token tail
    pages = alloc.alloc(2)
    idx.insert(toks, pages)
    full, tail = idx.match(toks[:4] + [toks[4], toks[5], 888, 777])
    assert full == [pages[0]]
    assert tail == (pages[1], 2)               # 2 of 3 tail tokens shared


def test_index_holds_pages_alive_and_eviction_releases_them():
    alloc, idx = _index(num_pages=8)
    pages = alloc.alloc(2)
    idx.insert(list(range(50, 58)), pages)     # 2 full pages
    alloc.free(pages)                          # the writer's own holds drop
    assert alloc.used_count == 2               # ...but the index keeps them
    assert idx.evict_one() and idx.evict_one()
    assert not idx.evict_one()                 # empty
    assert alloc.used_count == 0 and alloc.free_count == 7


def test_index_evicts_leaves_before_interior_pages():
    """Evicting a chain interior first would orphan (unreachable but
    ref-held) descendants; leaves must go first even when the interior is
    least recently used."""
    alloc, idx = _index()
    pages = alloc.alloc(3)
    idx.insert(list(range(10, 22)), pages)     # chain of 3 full pages
    alloc.free(pages)
    # touch nothing: entry LRU order == insertion order (root oldest)
    assert idx.evict_one()
    assert alloc.used_count == 2               # deepest page went first
    full, _ = idx.match(list(range(10, 22)))
    assert full == pages[:2]                   # prefix chain still intact


def test_eviction_prefers_reclaimable_pages():
    """Regression: pool pressure must reclaim pages only the index holds,
    not strip the (older, LRU-first) chain a running sequence still shares —
    that frees nothing and destroys the cache later requests would hit."""
    alloc, idx = _index(num_pages=16, page_size=4)
    shared = alloc.alloc(3)                    # a running seq holds these too
    idx.insert(list(range(100, 112)), shared)
    donated = alloc.alloc(3)
    idx.insert(list(range(200, 212)), donated)
    alloc.free(donated)                        # finished seq: index-only now
    free0 = alloc.free_count
    assert idx.evict_one() and idx.evict_one()
    assert alloc.free_count == free0 + 2       # freed donated pages...
    full, _ = idx.match(list(range(100, 112)))
    assert full == shared                      # ...not the shared chain


def test_index_incremental_holds_track_entries_exactly():
    """Regression for the eviction-burst rescan: the index now maintains its
    page->hold-count map incrementally. After any interleaving of inserts
    (including re-registering the same page as a longer prefix, which gives
    one page both a partial and a full entry) and evictions, the maintained
    map must equal a from-scratch rebuild, and reclaimable() must agree with
    the old rebuild-based definition."""
    alloc, idx = _index(num_pages=64, page_size=4)
    rng = np.random.default_rng(29)

    def rebuilt():
        holds = {}
        for e in idx._full.values():
            holds[e.page] = holds.get(e.page, 0) + 1
        for bucket in idx._partials.values():
            for e in bucket.values():
                holds[e.page] = holds.get(e.page, 0) + 1
        return holds

    def check():
        holds = rebuilt()
        assert idx._holds == holds
        assert idx.reclaimable() == sum(
            1 for p, n in holds.items() if alloc.ref_count(p) == n)

    writer_held = []
    for step in range(40):
        op = rng.integers(0, 3)
        if op < 2:                              # insert a random prefix
            n_tok = int(rng.integers(2, 15))
            pages = alloc.alloc(-(-n_tok // 4))
            if pages is None:
                continue
            base = int(rng.integers(0, 4)) * 100
            toks = [base + t for t in range(n_tok)]
            idx.insert(toks, pages)
            if rng.integers(0, 2):              # half the writers finish
                alloc.free(pages)
            else:
                writer_held.append(pages)
            # sometimes re-register the same tokens grown by a few more:
            # the old tail page ends up under a full entry too
            if rng.integers(0, 2) and n_tok % 4:
                extra = alloc.alloc(1)
                if extra is not None:
                    idx.insert(toks + [base + 50], pages + extra)
                    alloc.free(extra)
        else:
            idx.evict_one()
        check()
    while idx.evict_one():
        check()
    assert idx._holds == {}
    for pages in writer_held:
        alloc.free(pages)
    assert alloc.used_count == 0


def test_index_keeps_existing_entry_on_duplicate_insert():
    alloc, idx = _index()
    p1 = alloc.alloc(1)
    p2 = alloc.alloc(1)
    toks = list(range(30, 34))
    idx.insert(toks, p1)
    idx.insert(toks, p2)                       # same prefix, different page
    full, _ = idx.match(toks + [0])
    assert full == p1                          # first writer wins
    assert alloc.ref_count(p2[0]) == 1         # duplicate took no index hold


# ----------------------------------------------------------------- e2e helpers ---

@pytest.fixture(scope="module")
def fp32_llama():
    arch = smoke_config("llama3.2-3b")
    arch = dataclasses.replace(arch, dtype="float32", param_dtype="float32")
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    return arch, model, params


def _static_greedy(model, params, prompts, gens):
    """Per-request static decode (batch 1): the reference token stream."""
    out = []
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)
    for i, prompt in enumerate(prompts):
        plen, glen = len(prompt), gens[i]
        caches = model.init_caches(None, 1, plen + glen)
        logits, caches = prefill(params, caches,
                                 {"tokens": jnp.asarray([prompt])})
        tok = jnp.argmax(logits[:, -1], axis=-1)
        ids = [int(tok[0])]
        for s in range(glen - 1):
            logits, caches = decode(
                params, caches,
                {"tokens": tok[:, None],
                 "positions": jnp.full((1,), plen + s, jnp.int32)})
            tok = jnp.argmax(logits[:, -1], axis=-1)
            ids.append(int(tok[0]))
        out.append(ids)
    return out


def _run_engine(model, params, prompts, gens, *, prefix_cache, num_slots=4,
                num_pages=48, page_size=8, max_seq_len=64, **kw):
    engine = ContinuousEngine(model, params, num_slots=num_slots,
                              num_pages=num_pages, page_size=page_size,
                              max_seq_len=max_seq_len,
                              prefix_cache=prefix_cache, **kw)
    res = engine.run([Request(uid=i, prompt=prompts[i],
                              max_new_tokens=gens[i])
                      for i in range(len(prompts))])
    return engine, [res[i]["tokens"] for i in range(len(prompts))]


# ------------------------------------------------------------------ e2e parity ---

def test_shared_system_prompt_dedup_and_parity(fp32_llama):
    """Requests sharing a system prompt: token streams identical to both the
    static engine and the cache-off engine, most prompt tokens served from
    cache, shared pages stored once, and the divergent tail page CoW-copied
    (the shared prefix is deliberately not page-aligned)."""
    arch, model, params = fp32_llama
    rng = np.random.default_rng(21)
    system = list(map(int, rng.integers(5, arch.vocab_size, 19)))  # 2 pages+3
    prompts = [system + list(map(int, rng.integers(5, arch.vocab_size, 4)))
               for _ in range(4)]
    gens = [5, 8, 4, 6]
    ref = _static_greedy(model, params, prompts, gens)

    e_off, t_off = _run_engine(model, params, prompts, gens,
                               prefix_cache=False)
    e_on, t_on = _run_engine(model, params, prompts, gens, prefix_cache=True)
    assert t_off == ref and t_on == ref

    # 3 followers x (16 aligned + 3 CoW-tail) tokens come from the cache
    assert e_off.cached_prefill_tokens == 0
    assert e_on.cached_prefill_tokens == 3 * 19
    assert e_on.prefill_tokens == e_off.prefill_tokens - 3 * 19
    assert e_on.cow_copies == 3
    # drained: no logical tokens live, but the index keeps the cache resident
    assert e_on.live_kv_tokens == 0
    assert e_on.pages_in_use > 0
    idx = e_on.scheduler.prefix
    assert idx.hits >= 3


def test_repeat_trace_is_almost_free(fp32_llama):
    """Serving the same prompts twice through one engine: the second wave's
    prefill is one suffix token per request (everything else prefix-hits)."""
    arch, model, params = fp32_llama
    rng = np.random.default_rng(22)
    prompts = [list(map(int, rng.integers(5, arch.vocab_size, 17)))
               for _ in range(3)]
    gens = [4, 4, 4]
    engine = ContinuousEngine(model, params, num_slots=3, num_pages=48,
                              page_size=8, max_seq_len=64, prefix_cache=True)
    first = engine.run([Request(uid=i, prompt=prompts[i], max_new_tokens=4)
                        for i in range(3)])
    tokens_before = engine.prefill_tokens
    second = engine.run([Request(uid=10 + i, prompt=prompts[i],
                                 max_new_tokens=4) for i in range(3)])
    for i in range(3):
        assert second[10 + i]["tokens"] == first[i]["tokens"]
    # 17 tokens = 2 full pages + 1 tail token; the tail page was registered
    # partially filled, so the repeat computes the 1-token suffix only
    assert engine.prefill_tokens - tokens_before == 3 * 1


def test_chunked_prefill_long_prompt_parity(fp32_llama):
    """A prompt spanning several chunks (and a tiny chunk size) must not
    change a single token vs the static engine, including while another
    request decodes between its chunks."""
    arch, model, params = fp32_llama
    rng = np.random.default_rng(23)
    prompts = [list(map(int, rng.integers(5, arch.vocab_size, 45))),
               list(map(int, rng.integers(5, arch.vocab_size, 7)))]
    gens = [5, 12]
    ref = _static_greedy(model, params, prompts, gens)
    for chunk in (8, 16):
        engine, toks = _run_engine(model, params, prompts, gens,
                                   prefix_cache=True, num_slots=2,
                                   page_size=8, prefill_chunk=chunk)
        assert toks == ref, f"chunk={chunk} diverged"
        assert engine.prefill_tokens == sum(len(p) for p in prompts)


# ----------------------------------------------- property sweep (hypothesis) -----

def _parity_case(fp32_llama, seed, page_size, num_pages, slots, share_prefix):
    arch, model, params = fp32_llama
    rng = np.random.default_rng(seed)
    shared = list(map(int, rng.integers(5, arch.vocab_size,
                                        int(rng.integers(6, 15)))))
    prompts, gens = [], []
    for _ in range(4):
        own = list(map(int, rng.integers(5, arch.vocab_size,
                                         int(rng.integers(2, 9)))))
        prompts.append((shared + own) if share_prefix else
                       list(map(int, rng.integers(5, arch.vocab_size,
                                                  int(rng.integers(4, 14))))))
        gens.append(int(rng.integers(3, 9)))
    ref = _static_greedy(model, params, prompts, gens)
    for prefix_cache in (False, True):
        engine, toks = _run_engine(model, params, prompts, gens,
                                   prefix_cache=prefix_cache,
                                   num_slots=slots, num_pages=num_pages,
                                   page_size=page_size, max_seq_len=32)
        assert toks == ref, (seed, page_size, num_pages, slots, share_prefix,
                             prefix_cache)
        assert engine.scheduler.cache.live_tokens == 0


if st is not None:
    @settings(max_examples=6, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        page_size=st.sampled_from([4, 8]),
        num_pages=st.integers(10, 18),
        slots=st.sampled_from([2, 3]),
        share_prefix=st.booleans(),
    )
    def test_greedy_parity_property_sweep(fp32_llama, seed, page_size,
                                          num_pages, slots, share_prefix):
        """Randomized tiny page pools (tight enough to recycle and preempt):
        greedy outputs must be token-identical across {static, continuous,
        continuous+prefix-cache}."""
        _parity_case(fp32_llama, seed, page_size, num_pages, slots,
                     share_prefix)
else:
    def test_greedy_parity_property_sweep():
        pytest.importorskip("hypothesis")


def test_greedy_parity_smoke_without_hypothesis(fp32_llama):
    """One pinned instance of the property (runs even without hypothesis)."""
    _parity_case(fp32_llama, seed=1234, page_size=4, num_pages=12, slots=2,
                 share_prefix=True)
