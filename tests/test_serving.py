"""Continuous-batching engine: allocator invariants, scheduler recycling,
and exact greedy parity with the static engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import (ContinuousEngine, PageAllocator, Request,
                           Scheduler, pages_needed)
from repro.serving.kv_cache import NULL_PAGE


# ------------------------------------------------------------------ allocator ----

def test_allocator_alloc_free_roundtrip():
    a = PageAllocator(8)                       # pages 1..7 usable
    assert a.free_count == 7
    pages = a.alloc(3)
    assert len(set(pages)) == 3 and NULL_PAGE not in pages
    assert a.free_count == 4 and a.used_count == 3
    a.free(pages)
    assert a.free_count == 7 and a.used_count == 0


def test_allocator_never_double_allocates():
    a = PageAllocator(16)
    seen = set()
    held = []
    for _ in range(5):
        pages = a.alloc(3)
        assert not (seen & set(pages)), "page handed out twice while held"
        seen |= set(pages)
        held.append(pages)
    a.free(held.pop())
    more = a.alloc(3)                          # recycled ids are fine...
    assert not (set(more) & set().union(*held))  # ...but never held twice


def test_allocator_oom_refusal_is_all_or_nothing():
    a = PageAllocator(4)                       # 3 usable pages
    assert a.alloc(4) is None
    assert a.free_count == 3                   # refused alloc took nothing
    pages = a.alloc(3)
    assert pages is not None and a.alloc(1) is None
    a.free(pages)


def test_allocator_rejects_double_free_and_null_page():
    a = PageAllocator(8)
    pages = a.alloc(2)
    a.free(pages)
    with pytest.raises(ValueError):
        a.free(pages)
    with pytest.raises(ValueError):
        a.free([NULL_PAGE])


def test_allocator_refcounts_shared_pages():
    """A page shared N ways is stored once and survives until the last hold
    drops — the memory dedup prefix caching is built on."""
    a = PageAllocator(8)
    (pg,) = a.alloc(1)
    a.incref(pg)
    a.incref(pg)                               # three holds
    assert a.ref_count(pg) == 3
    assert a.used_count == 1                   # stored once
    a.free([pg])
    a.free([pg])
    assert a.used_count == 1 and a.free_count == 6   # still held
    a.free([pg])
    assert a.used_count == 0 and a.free_count == 7   # last hold: recycled
    with pytest.raises(ValueError):
        a.free([pg])
    with pytest.raises(ValueError):
        a.incref(pg)                           # can't share a dead page


def test_pages_needed():
    assert pages_needed(1, 16) == 1
    assert pages_needed(16, 16) == 1
    assert pages_needed(17, 16) == 2


# ------------------------------------------------------------------ scheduler ----

def _req(uid, plen=8, gen=4):
    return Request(uid=uid, prompt=list(range(5, 5 + plen)),
                   max_new_tokens=gen)


def test_scheduler_admission_by_free_pages():
    # 4 usable pages, page_size 4: an 8-token prompt needs 3 pages (ctx+1)
    s = Scheduler(num_slots=4, num_pages=5, page_size=4, max_pages_per_seq=8)
    s.submit(_req(0))
    s.submit(_req(1))
    seq = s.admit_next()
    assert seq is not None and seq.request.uid == 0
    assert s.admit_next() is None              # 1 free page < 3 needed
    s.finish(seq)
    assert s.admit_next().request.uid == 1     # pages recycled -> admitted


def test_scheduler_slot_recycling():
    s = Scheduler(num_slots=2, num_pages=64, page_size=4, max_pages_per_seq=8)
    for uid in range(4):
        s.submit(_req(uid))
    a, b = s.admit_next(), s.admit_next()
    assert {a.slot, b.slot} == {0, 1}
    assert s.admit_next() is None              # both slots busy
    s.finish(a)
    c = s.admit_next()
    assert c.slot == a.slot                    # freed slot reused
    assert s.cache.seq_lens[c.slot] == len(c.request.prompt)
    s.finish(b), s.finish(c)
    d = s.admit_next()
    assert d is not None and not s.queue
    s.finish(d)
    assert s.allocator.used_count == 0         # everything returned


def test_scheduler_page_growth_and_preemption():
    # admission leaves exactly one page of headroom (anti-thrash rule); once
    # growth burns it, growing the older sequence must preempt the newer
    s = Scheduler(num_slots=2, num_pages=8, page_size=4, max_pages_per_seq=8)
    s.submit(_req(0, plen=8, gen=16))          # 3 pages
    s.submit(_req(1, plen=8, gen=16))          # 3 pages + 1 headroom
    s0, s1 = s.admit_next(), s.admit_next()
    assert s0 is not None and s1 is not None
    assert s.allocator.free_count == 1
    s.cache.seq_lens[s0.slot] = 12             # slot 0 full: next token -> page 4
    assert s.ensure_capacity() == []           # headroom page absorbs growth
    assert s.cache.allocated_pages(s0.slot) == 4
    s.cache.seq_lens[s0.slot] = 16             # full again: next -> page 5
    preempted = s.ensure_capacity()
    assert [p.request.uid for p in preempted] == [1]
    assert s.queue[0].uid == 1                 # requeued at the front
    assert s.cache.allocated_pages(s0.slot) == 5


def test_scheduler_headroom_blocks_zero_slack_admission():
    """With sequences already running, admission must leave >= 1 free page —
    a zero-slack admit would be the first preemption victim the moment any
    neighbour grows (admit/preempt thrash)."""
    s = Scheduler(num_slots=2, num_pages=7, page_size=4, max_pages_per_seq=8)
    s.submit(_req(0, plen=8, gen=16))          # 3 pages, nothing running: ok
    s.submit(_req(1, plen=8, gen=16))          # would leave 0 free: refused
    s0 = s.admit_next()
    assert s0 is not None
    assert s.admit_next() is None
    assert s.allocator.free_count == 3         # refused admit took nothing
    s.finish(s0)
    assert s.admit_next().request.uid == 1     # pool empty again: admitted


def test_scheduler_rejects_oversized_request_and_keeps_serving():
    """A context that can never fit in max_pages_per_seq must fail that one
    request (surfaced via take_rejected), not raise and kill the engine."""
    s = Scheduler(num_slots=2, num_pages=64, page_size=4, max_pages_per_seq=4)
    s.submit(_req(0, plen=8))
    s.submit(_req(1, plen=40))                 # 11 pages > 4: impossible
    s.submit(_req(2, plen=8))
    a = s.admit_next()
    assert a is not None and a.request.uid == 0
    b = s.admit_next()                         # skips over the doomed request
    assert b is not None and b.request.uid == 2
    assert [r.uid for r in s.take_rejected()] == [1]
    assert s.take_rejected() == []             # drained
    s.finish(a), s.finish(b)
    assert s.allocator.used_count == 0


# ------------------------------------------------------------------ e2e parity ---

def _fp32_model(name):
    arch = smoke_config(name)
    arch = dataclasses.replace(arch, dtype="float32", param_dtype="float32")
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    return arch, model, params


def _static_greedy(model, params, prompts, gens):
    """Per-request static decode (batch 1): the reference token stream."""
    out = []
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)
    for i, prompt in enumerate(prompts):
        plen, glen = len(prompt), gens[i]
        caches = model.init_caches(None, 1, plen + glen)
        logits, caches = prefill(params, caches,
                                 {"tokens": jnp.asarray([prompt])})
        tok = jnp.argmax(logits[:, -1], axis=-1)
        ids = [int(tok[0])]
        for s in range(glen - 1):
            logits, caches = decode(
                params, caches,
                {"tokens": tok[:, None],
                 "positions": jnp.full((1,), plen + s, jnp.int32)})
            tok = jnp.argmax(logits[:, -1], axis=-1)
            ids.append(int(tok[0]))
        out.append(ids)
    return out


@pytest.mark.parametrize("name", ["llama3.2-3b", "qwen2-vl-2b"])
def test_continuous_matches_static_greedy(name):
    arch, model, params = _fp32_model(name)
    rng = np.random.default_rng(3)
    prompts = [list(map(int, rng.integers(5, arch.vocab_size, rng.integers(6, 14))))
               for _ in range(4)]
    gens = [6, 11, 4, 9]                       # ragged generation lengths
    ref = _static_greedy(model, params, prompts, gens)

    engine = ContinuousEngine(model, params, num_slots=4, num_pages=48,
                              page_size=8, max_seq_len=64)
    res = engine.run([Request(uid=i, prompt=prompts[i], max_new_tokens=gens[i])
                      for i in range(4)])
    for i in range(4):
        assert res[i]["tokens"] == ref[i], f"request {i} diverged"
    assert engine.live_kv_tokens == 0          # all pages recycled


def test_continuous_matches_static_under_recycling_and_preemption():
    """slots < requests and a page pool too small for all of them: recycling
    and recompute-preemption must not change a single greedy token.
    (prefix_cache off so the drained pool is exactly empty — the index would
    deliberately retain pages.)"""
    arch, model, params = _fp32_model("llama3.2-3b")
    rng = np.random.default_rng(7)
    prompts = [list(map(int, rng.integers(5, arch.vocab_size, 12)))
               for _ in range(5)]
    gens = [4, 16, 7, 12, 9]
    ref = _static_greedy(model, params, prompts, gens)

    engine = ContinuousEngine(model, params, num_slots=2, num_pages=10,
                              page_size=4, max_seq_len=32,
                              prefix_cache=False)
    res = engine.run([Request(uid=i, prompt=prompts[i], max_new_tokens=gens[i])
                      for i in range(5)])
    for i in range(5):
        assert res[i]["tokens"] == ref[i], f"request {i} diverged"
    assert engine.prefills > 5                 # preemption actually happened
    assert engine.scheduler.allocator.used_count == 0


def test_overlong_prompt_gets_error_result_not_engine_death():
    """Regression: one request whose context exceeds max_pages_per_seq used
    to raise out of admit_next mid-trace, killing every in-flight request.
    It must come back as an error result while the rest serve normally."""
    arch, model, params = _fp32_model("llama3.2-3b")
    rng = np.random.default_rng(11)
    ok_prompts = [list(map(int, rng.integers(5, arch.vocab_size, 10)))
                  for _ in range(2)]
    ref = _static_greedy(model, params, ok_prompts, [5, 7])
    engine = ContinuousEngine(model, params, num_slots=2, num_pages=32,
                              page_size=8, max_seq_len=32)   # 4 pages/seq
    reqs = [Request(uid=0, prompt=ok_prompts[0], max_new_tokens=5),
            Request(uid=1, prompt=list(range(5, 5 + 40)),    # needs 6 pages
                    max_new_tokens=5),
            Request(uid=2, prompt=ok_prompts[1], max_new_tokens=7)]
    res = engine.run(reqs)
    assert "error" in res[1] and res[1]["tokens"] == []
    assert res[0]["tokens"] == ref[0]
    assert res[2]["tokens"] == ref[1]


def test_generation_outgrowing_max_seq_len_truncates_not_crashes():
    """Regression: a prompt that fits but whose max_new_tokens would outgrow
    the page table used to die mid-trace in append_page ('page table full'),
    discarding every in-flight request. It must truncate at cache capacity
    and the other requests must be untouched."""
    arch, model, params = _fp32_model("llama3.2-3b")
    rng = np.random.default_rng(19)
    big = list(map(int, rng.integers(5, arch.vocab_size, 16)))
    ok = list(map(int, rng.integers(5, arch.vocab_size, 8)))
    ref_ok = _static_greedy(model, params, [ok], [5])[0]
    engine = ContinuousEngine(model, params, num_slots=2, num_pages=32,
                              page_size=8, max_seq_len=32)   # 32-token cap
    res = engine.run([Request(uid=0, prompt=big, max_new_tokens=40),
                      Request(uid=1, prompt=ok, max_new_tokens=5)])
    assert len(res[0]["tokens"]) == 32 - 16        # truncated at capacity
    assert res[1]["tokens"] == ref_ok
    assert engine.live_kv_tokens == 0


def test_admission_headroom_bounds_reprefills():
    """Regression for admit/preempt thrash: with a pool where the second
    request fits only with zero slack, the old scheduler admitted it, paid
    its prefill, then chose it as the preemption victim as soon as the first
    sequence grew — re-prefilling on a loop. With admission headroom, total
    prefill completions stay at (admissions + genuine preemptions)."""
    arch, model, params = _fp32_model("llama3.2-3b")
    rng = np.random.default_rng(13)
    prompts = [list(map(int, rng.integers(5, arch.vocab_size, 8)))
               for _ in range(2)]
    gens = [16, 16]
    ref = _static_greedy(model, params, prompts, gens)
    engine = ContinuousEngine(model, params, num_slots=2, num_pages=10,
                              page_size=4, max_seq_len=32,
                              prefix_cache=False)
    res = engine.run([Request(uid=i, prompt=prompts[i], max_new_tokens=gens[i])
                      for i in range(2)])
    for i in range(2):
        assert res[i]["tokens"] == ref[i], f"request {i} diverged"
    # 2 admissions + at most one growth-driven preemption/re-admission; the
    # thrash regression showed up as a prefill per crossed page boundary
    assert engine.prefills <= 3


def test_preempted_midprefill_sequence_readmits_instead_of_stalling():
    """Regression: preempting a sequence that is mid-prefill left its stale
    entry gating admission (the prefix-cache serialized-admission gate); if
    the other sequence finished on that same iteration the engine saw
    {nothing running, non-empty queue} and raised 'queue stalled' for a
    perfectly admittable request. Forces exactly that interleaving: the
    victim must simply be re-admitted and complete."""
    arch, model, params = _fp32_model("llama3.2-3b")
    rng = np.random.default_rng(17)
    # timing: uid0 (8-tok prompt, 4-tok chunks) prefills over iterations 1-2
    # and decodes from iteration 2; uid1 is admitted at iteration 3 and is
    # mid-prefill there, which is exactly when uid0's final decode runs
    prompts = [list(map(int, rng.integers(5, arch.vocab_size, 8))),
               list(map(int, rng.integers(5, arch.vocab_size, 12)))]
    gens = [3, 3]
    ref = _static_greedy(model, params, prompts, gens)
    engine = ContinuousEngine(model, params, num_slots=2, num_pages=32,
                              page_size=4, max_seq_len=48,
                              prefix_cache=True, prefill_chunk=4)
    sched = engine.scheduler
    orig = sched.ensure_capacity
    forced = []

    def force_preempt_midprefill():
        out = orig()
        victim = next((s for s in sched.running.values()
                       if s.prefilled < s.prefill_target), None)
        if not forced and victim is not None and len(sched.running) > 1:
            sched._preempt(victim)      # simulated pool pressure
            out.append(victim)
            forced.append(victim.request.uid)
        return out

    sched.ensure_capacity = force_preempt_midprefill
    res = engine.run([Request(uid=i, prompt=prompts[i],
                              max_new_tokens=gens[i]) for i in range(2)])
    assert forced == [1], "scenario must actually fire"
    for i in range(2):
        assert res[i]["tokens"] == ref[i], f"request {i} diverged"


def test_eos_stops_generation_early():
    arch, model, params = _fp32_model("llama3.2-3b")
    prompt = list(range(5, 15))
    ref = _static_greedy(model, params, [prompt], [12])[0]
    eos = ref[3]                               # force an early stop
    stop = ref.index(eos) + 1                  # first occurrence wins
    engine = ContinuousEngine(model, params, num_slots=2, num_pages=32,
                              page_size=8, max_seq_len=64)
    res = engine.run([Request(uid=0, prompt=prompt, max_new_tokens=12,
                              eos_id=eos)])
    assert res[0]["tokens"] == ref[:stop]
    assert engine.live_kv_tokens == 0
