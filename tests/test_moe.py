"""MoE: capacity semantics, no-drop equivalence with dense expert mixture,
router weight normalization, aux loss bounds."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import moe as moe_lib
from repro.models.layers import silu


def _arch(cf=8.0, top_k=2, experts=4):
    base = smoke_config("deepseek-moe-16b")
    return dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, capacity_factor=cf,
                                      top_k=top_k, num_experts=experts,
                                      num_shared_experts=0))


def _dense_mixture(arch, p, x):
    """Reference: run every expert on every token, weight by normalized top-k."""
    moe = arch.moe
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_w, top_ids = jax.lax.top_k(probs, moe.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    w = p["experts"]
    outs = []
    for e in range(moe.num_experts):
        h = silu(x @ w["w1"][e]) * (x @ w["w3"][e])
        outs.append(h @ w["w2"][e])
    outs = jnp.stack(outs, axis=-2)                      # [B,S,E,D]
    gate = jnp.zeros(probs.shape).at[
        jnp.arange(x.shape[0])[:, None, None],
        jnp.arange(x.shape[1])[None, :, None], top_ids].set(top_w)
    return jnp.einsum("bse,bsed->bsd", gate, outs)


def test_moe_matches_dense_mixture_when_no_drops():
    arch = _arch(cf=8.0)
    key = jax.random.key(0)
    p = moe_lib.init_moe(key, arch, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, arch.d_model))
    y, aux = moe_lib.apply_moe(arch, p, x)
    y_ref = _dense_mixture(arch, p, x)
    np.testing.assert_allclose(y, y_ref, atol=1e-4)
    assert 0.0 <= float(aux) < 1.0


def test_capacity_drops_reduce_output_norm():
    x = jax.random.normal(jax.random.key(1), (2, 32, 128))
    arch_hi = _arch(cf=8.0)
    arch_lo = dataclasses.replace(
        arch_hi, moe=dataclasses.replace(arch_hi.moe, capacity_factor=0.25))
    p = moe_lib.init_moe(jax.random.key(0), arch_hi, jnp.float32)
    y_hi, _ = moe_lib.apply_moe(arch_hi, p, x)
    y_lo, _ = moe_lib.apply_moe(arch_lo, p, x)
    # dropped tokens contribute zero -> strictly less mass
    assert float(jnp.sum(jnp.abs(y_lo))) < float(jnp.sum(jnp.abs(y_hi)))


def test_capacity_per_row():
    arch = _arch()
    assert moe_lib.capacity_per_row(1, arch.moe) >= 1
    c = moe_lib.capacity_per_row(4096, arch.moe)
    assert c * arch.moe.num_experts >= 4096 * arch.moe.top_k


def test_eff_capacity_reproduces_unpadded_dispatch():
    """The chunked-prefill contract: a prompt served in one PADDED chunk
    must drop exactly the tokens a full-(unpadded-)prompt dispatch drops.
    Trailing padding can never displace a real token (the stable expert
    sort keeps padded entries behind every real one), but the padded shape
    inflates ``capacity_per_row`` — ``eff_capacity`` pins the threshold to
    the real prompt's bucket, making real-token outputs bit-identical to
    the unpadded run even when capacity binds."""
    arch = _arch(cf=0.6)                       # capacity binds hard
    p = moe_lib.init_moe(jax.random.key(0), arch, jnp.float32)
    n_valid, s = 10, 16
    x_pad = jax.random.normal(jax.random.key(1), (1, s, arch.d_model))
    x_real = x_pad[:, :n_valid]
    cap_real = moe_lib.capacity_per_row(n_valid, arch.moe)
    y_pad, _ = moe_lib.apply_moe(arch, p, x_pad,
                                 eff_capacity=jnp.int32(cap_real))
    y_real, _ = moe_lib.apply_moe(arch, p, x_real)
    assert jnp.array_equal(y_pad[:, :n_valid], y_real)
    # negative control: without eff_capacity, the padded shape's larger
    # bucket keeps tokens the unpadded dispatch drops — i.e. this scenario
    # really exercises bound capacity
    u_pad, _ = moe_lib.apply_moe(arch, p, x_pad)
    assert not jnp.array_equal(u_pad[:, :n_valid], y_real)
    # eff_capacity >= the shape's own bucket is an exact no-op
    cap_shape = moe_lib.capacity_per_row(s, arch.moe)
    y_same, _ = moe_lib.apply_moe(arch, p, x_pad,
                                  eff_capacity=jnp.int32(cap_shape))
    assert jnp.array_equal(y_same, u_pad)
