"""tools/check_bench.py: the CI benchmark regression gate's compare logic.

Pure-dict tests (no benchmark run): regressions beyond tolerance fail,
improvements and in-tolerance noise pass, and a *partial* artifact — a
baseline metric missing from the current result — fails rather than being
skipped, which is the whole point of gating the upload.
"""
import copy
import importlib.util
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def _load_check_bench():
    spec = importlib.util.spec_from_file_location(
        "check_bench", ROOT / "tools" / "check_bench.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


cb = _load_check_bench()

BASELINE = {
    "rates": {
        "4": {"continuous": {"tok_s": 100.0}, "static": {"tok_s": 50.0}},
        "inf": {"continuous": {"tok_s": 200.0}},
    },
    "shared_prefix": {
        "off": {"tok_s": 60.0, "ttft_ms": 1000.0},
        "on": {"tok_s": 80.0, "ttft_ms": 700.0},
    },
    "sampled": {"greedy": {"tok_s": 150.0}, "sampled": {"tok_s": 120.0},
                "sampled_ref": {"tok_s": 90.0},
                "sampler_overhead_pct": 25.0,
                "sampler_overhead_pct_ref": 66.7,
                "diverged_requests": 8, "diverged_streams": 0},
    "families": {
        "mamba2-1.3b": {"tok_s": 40.0, "prefix_cache": "off: ssm"},
        "jamba-v0.1-52b": {"tok_s": 20.0, "prefix_cache": "off: ssm"},
        "deepseek-moe-16b": {"tok_s": 30.0, "prefix_cache": "on"},
    },
    "recompiles": {"engines": 12, "variants": 40, "traces": 40, "excess": 0},
    "multistep": {
        "n1": {"tok_s": 300.0, "dispatches_per_token": 0.30},
        "n4": {"tok_s": 350.0, "dispatches_per_token": 0.09,
               "speedup_vs_n1": 1.17},
        "n16": {"tok_s": 380.0, "dispatches_per_token": 0.04,
                "speedup_vs_n1": 1.27},
        "diverged_streams": 0,
    },
    "decode_fusion": {
        "unfused": {"tok_s": 290.0},
        "fused": {"tok_s": 295.0},
        "fused_n4": {"tok_s": 340.0},
        "speedup_vs_unfused": 1.02,
        "diverged_streams": 0,
        "hbm_bytes_saved_per_token": 120_000,
        "hbm_accounting": {"logits_bytes_per_token": 100_000,
                           "residual_bytes_per_token": 20_000,
                           "fused_norm_sites": 7},
    },
}


def _failed(rows):
    return [r["metric"] for r in rows if not r["ok"]]


def test_identical_results_pass():
    assert _failed(cb.compare(copy.deepcopy(BASELINE), BASELINE, 0.2)) == []


def test_metric_inventory_matches_baseline_sections():
    paths = [m[0] for m in cb.iter_metrics(BASELINE)]
    assert "rates.4.continuous.tok_s" in paths
    assert "rates.inf.continuous.tok_s" in paths
    assert "shared_prefix.on.ttft_ms" in paths
    assert "sampled.sampled.tok_s" in paths
    assert "sampled.sampled_ref.tok_s" in paths
    assert "sampled.sampler_overhead_pct" in paths
    assert "sampled.diverged_streams" in paths
    assert "families.jamba-v0.1-52b.tok_s" in paths
    assert "multistep.n4.tok_s" in paths
    assert "multistep.n16.dispatches_per_token" in paths
    assert "multistep.n4.speedup_vs_n1" in paths
    assert "multistep.diverged_streams" in paths
    assert "decode_fusion.unfused.tok_s" in paths
    assert "decode_fusion.fused.tok_s" in paths
    assert "decode_fusion.fused_n4.tok_s" in paths
    assert "decode_fusion.speedup_vs_unfused" in paths
    assert "decode_fusion.diverged_streams" in paths
    # the analytic HBM accounting is context (a constant of the arch), not a
    # gated perf number
    assert not any("hbm" in p for p in paths)
    # static engine numbers are context, not gated; the reference sampler's
    # overhead is context too (only its absolute tok/s is gated)
    assert not any("static" in p for p in paths)
    assert "sampled.sampler_overhead_pct_ref" not in paths


def test_baseline_without_families_section_fails():
    """`families` is a REQUIRED baseline section: a baseline that predates
    the hybrid/SSM/MoE serving sweep would silently un-gate it — the gate
    must demand a re-baseline instead."""
    old = {k: v for k, v in copy.deepcopy(BASELINE).items()
           if k != "families"}
    rows = cb.compare(copy.deepcopy(old), old, 0.2)
    missing = [r for r in rows if not r["ok"]]
    assert [r["metric"] for r in missing] == ["families.<section>"]
    assert "re-baseline" in missing[0]["note"]


def test_families_regression_and_partial_artifact_fail():
    cur = copy.deepcopy(BASELINE)
    cur["families"]["mamba2-1.3b"]["tok_s"] = 40.0 * 0.5       # -50%
    assert _failed(cb.compare(cur, BASELINE, 0.2)) == \
        ["families.mamba2-1.3b.tok_s"]
    cur = {k: v for k, v in copy.deepcopy(BASELINE).items()
           if k != "families"}
    rows = cb.compare(cur, BASELINE, 0.2)
    assert all("MISSING" in r["note"] for r in rows if not r["ok"])
    assert {r["metric"] for r in rows if not r["ok"]} == {
        "families.mamba2-1.3b.tok_s", "families.jamba-v0.1-52b.tok_s",
        "families.deepseek-moe-16b.tok_s"}


def test_recompile_excess_gated_at_exactly_zero():
    """``recompiles.excess`` uses direction "zero": ONE retrace fails the
    gate no matter how loose the tolerance — a recompile after warmup is a
    correctness bug, not a perf number tolerance should forgive."""
    cur = copy.deepcopy(BASELINE)
    cur["recompiles"]["excess"] = 1
    rows = cb.compare(cur, BASELINE, tolerance=10.0)
    assert _failed(rows) == ["recompiles.excess"]
    assert "correctness invariant" in \
        [r for r in rows if r["metric"] == "recompiles.excess"][0]["note"]


def test_baseline_without_recompiles_section_fails():
    old = {k: v for k, v in copy.deepcopy(BASELINE).items()
           if k != "recompiles"}
    rows = cb.compare(copy.deepcopy(old), old, 0.2)
    missing = [r for r in rows if not r["ok"]]
    assert [r["metric"] for r in missing] == ["recompiles.<section>"]
    assert "re-baseline" in missing[0]["note"]


def test_baseline_without_sampled_section_fails():
    """`sampled` became REQUIRED with the fused-sampler gates: a baseline
    predating them would silently drop the sampler-overhead and
    fused-vs-reference divergence coverage."""
    old = {k: v for k, v in copy.deepcopy(BASELINE).items()
           if k != "sampled"}
    rows = cb.compare(copy.deepcopy(old), old, 0.2)
    missing = [r for r in rows if not r["ok"]]
    assert [r["metric"] for r in missing] == ["sampled.<section>"]


def test_baseline_without_multistep_section_fails():
    """`multistep` became REQUIRED with the compiled decode loop: a baseline
    predating it would silently drop the dispatch-bound and N-vs-1 stream
    divergence coverage."""
    old = {k: v for k, v in copy.deepcopy(BASELINE).items()
           if k != "multistep"}
    rows = cb.compare(copy.deepcopy(old), old, 0.2)
    missing = [r for r in rows if not r["ok"]]
    assert [r["metric"] for r in missing] == ["multistep.<section>"]
    assert "re-baseline" in missing[0]["note"]


def test_baseline_without_decode_fusion_section_fails():
    """`decode_fusion` became REQUIRED with the fused decode residual
    stream: a baseline predating it would silently drop the fused-vs-unfused
    zero-divergence gate."""
    old = {k: v for k, v in copy.deepcopy(BASELINE).items()
           if k != "decode_fusion"}
    rows = cb.compare(copy.deepcopy(old), old, 0.2)
    missing = [r for r in rows if not r["ok"]]
    assert [r["metric"] for r in missing] == ["decode_fusion.<section>"]
    assert "re-baseline" in missing[0]["note"]


def test_decode_fusion_gate_directions():
    """The fused/unfused ratio is a noise floor (tolerance applies: on CPU
    the fused graph is op-identical so ~1.0x is healthy), but ONE
    fused-vs-unfused token mismatch fails at any tolerance — the fusion's
    entire contract is bit-identical streams."""
    cur = copy.deepcopy(BASELINE)
    cur["decode_fusion"]["speedup_vs_unfused"] = 1.02 * 0.9    # -10% < 20%
    assert _failed(cb.compare(cur, BASELINE, 0.2)) == []
    cur["decode_fusion"]["speedup_vs_unfused"] = 1.02 * 0.5    # a real cliff
    assert _failed(cb.compare(cur, BASELINE, 0.2)) == \
        ["decode_fusion.speedup_vs_unfused"]
    cur = copy.deepcopy(BASELINE)
    cur["decode_fusion"]["diverged_streams"] = 1
    rows = cb.compare(cur, BASELINE, tolerance=10.0)
    assert _failed(rows) == ["decode_fusion.diverged_streams"]
    assert "correctness invariant" in \
        [r for r in rows if not r["ok"]][0]["note"]


def test_multistep_gate_directions():
    """dispatches_per_token regressing UP (more host syncs per token) fails;
    dropping further passes. One N>1-vs-N=1 token mismatch fails at any
    tolerance — the loop's whole contract is stream invisibility."""
    cur = copy.deepcopy(BASELINE)
    cur["multistep"]["n4"]["dispatches_per_token"] = 0.09 * 1.5
    assert _failed(cb.compare(cur, BASELINE, 0.2)) == \
        ["multistep.n4.dispatches_per_token"]
    cur["multistep"]["n4"]["dispatches_per_token"] = 0.09 * 0.5
    assert _failed(cb.compare(cur, BASELINE, 0.2)) == []
    cur["multistep"]["diverged_streams"] = 1
    assert _failed(cb.compare(cur, BASELINE, tolerance=10.0)) == \
        ["multistep.diverged_streams"]


def test_sampler_overhead_gated_in_absolute_points():
    """``sampler_overhead_pct`` uses direction "lower_points": the current
    overhead may exceed the baseline by at most 100 * tolerance percentage
    points. A relative bound would flap once the baseline is a small
    percentage (25% * 1.2 = 30% leaves 5 points of room; 25 + 20 = 45
    points is the intended slack)."""
    cur = copy.deepcopy(BASELINE)
    cur["sampled"]["sampler_overhead_pct"] = 44.0      # +19pp < 20pp slack
    assert _failed(cb.compare(cur, BASELINE, 0.2)) == []
    cur["sampled"]["sampler_overhead_pct"] = 46.0      # +21pp > 20pp slack
    assert _failed(cb.compare(cur, BASELINE, 0.2)) == \
        ["sampled.sampler_overhead_pct"]
    # an improvement always passes
    cur["sampled"]["sampler_overhead_pct"] = 1.0
    assert _failed(cb.compare(cur, BASELINE, 0.2)) == []


def test_fused_divergence_gated_at_exactly_zero():
    """One fused-vs-reference token mismatch fails the gate at any
    tolerance: the two filter implementations are bit-identical by
    contract, so divergence is a sampler bug, not noise."""
    cur = copy.deepcopy(BASELINE)
    cur["sampled"]["diverged_streams"] = 1
    rows = cb.compare(cur, BASELINE, tolerance=10.0)
    assert _failed(rows) == ["sampled.diverged_streams"]


def test_throughput_regression_beyond_tolerance_fails():
    cur = copy.deepcopy(BASELINE)
    cur["rates"]["inf"]["continuous"]["tok_s"] = 200.0 * 0.7   # -30%
    assert _failed(cb.compare(cur, BASELINE, 0.2)) == \
        ["rates.inf.continuous.tok_s"]


def test_ttft_direction_is_inverted():
    cur = copy.deepcopy(BASELINE)
    cur["shared_prefix"]["on"]["ttft_ms"] = 700.0 * 1.5        # slower TTFT
    assert _failed(cb.compare(cur, BASELINE, 0.2)) == \
        ["shared_prefix.on.ttft_ms"]
    # a FASTER TTFT (lower) of the same magnitude passes
    cur["shared_prefix"]["on"]["ttft_ms"] = 700.0 * 0.5
    assert _failed(cb.compare(cur, BASELINE, 0.2)) == []


def test_within_tolerance_noise_passes():
    cur = copy.deepcopy(BASELINE)
    cur["rates"]["4"]["continuous"]["tok_s"] = 100.0 * 0.85    # -15% < 20%
    cur["shared_prefix"]["off"]["ttft_ms"] = 1000.0 * 1.1
    assert _failed(cb.compare(cur, BASELINE, 0.2)) == []


def test_partial_artifact_fails_not_skips():
    cur = {k: v for k, v in copy.deepcopy(BASELINE).items()
           if k != "sampled"}
    rows = cb.compare(cur, BASELINE, 0.2)
    missing = [r for r in rows if not r["ok"]]
    assert {r["metric"] for r in missing} == \
        {"sampled.greedy.tok_s", "sampled.sampled.tok_s",
         "sampled.sampled_ref.tok_s", "sampled.sampler_overhead_pct",
         "sampled.diverged_streams"}
    assert all("MISSING" in r["note"] for r in missing)


def test_extra_current_sections_are_ignored():
    cur = copy.deepcopy(BASELINE)
    cur["tensor_parallel"] = {"tp": 2, "diverged_streams": 0}
    assert _failed(cb.compare(cur, BASELINE, 0.2)) == []


def test_empty_baseline_fails_loudly():
    rows = cb.compare({}, {}, 0.2)
    assert _failed(rows) == ["<none>"]


def test_cli_exit_codes(tmp_path):
    import json
    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    base.write_text(json.dumps(BASELINE))
    cur.write_text(json.dumps(BASELINE))
    assert cb.main([str(cur), str(base)]) == 0
    bad = copy.deepcopy(BASELINE)
    bad["sampled"]["greedy"]["tok_s"] = 1.0
    cur.write_text(json.dumps(bad))
    assert cb.main([str(cur), str(base)]) == 1
    # committed baseline must itself pass the gate's schema
    rows = cb.compare(
        json.loads((ROOT / "benchmarks" / "baselines" /
                    "serving.json").read_text()),
        json.loads((ROOT / "benchmarks" / "baselines" /
                    "serving.json").read_text()), 0.2)
    assert _failed(rows) == []
