"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    given = settings = st = None

from repro.kernels.bias_gelu import kernel as bg_kernel, ref as bg_ref
from repro.kernels.fused_lamb import ops as lamb_ops, ref as lamb_ref
from repro.kernels.fused_layernorm import kernel as ln_kernel, ref as ln_ref
from repro.kernels.fused_softmax import kernel as sm_kernel, ref as sm_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(256, 128), (512, 384), (1024, 1024)])
@pytest.mark.parametrize("rms", [True, False])
def test_layernorm_kernel_sweep(shape, dtype, rms):
    x = jax.random.normal(jax.random.key(0), shape, dtype)
    res = jax.random.normal(jax.random.key(1), shape, dtype)
    scale = jnp.ones((shape[-1],)) * 1.1
    bias = None if rms else jnp.full((shape[-1],), 0.05)
    yk = ln_kernel.fused_residual_layernorm(x, res, scale, bias, rms=rms,
                                            interpret=True)
    yr = ln_ref.fused_residual_layernorm(x, res, scale, bias, rms=rms)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32), atol=atol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_bias", [True, False])
def test_bias_gelu_kernel(dtype, with_bias):
    x = jax.random.normal(jax.random.key(2), (512, 256), dtype)
    b = jnp.linspace(-1, 1, 256).astype(dtype) if with_bias else None
    yk = bg_kernel.bias_gelu(x, b, interpret=True)
    yr = bg_ref.bias_gelu(x, b)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32), atol=atol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 128), (256, 384)])
@pytest.mark.parametrize("kind", ["rmsnorm", "layernorm"])
def test_decode_residual_norm_kernel_bitwise(shape, dtype, kind):
    """The decode-path residual+norm fusion is a BIT-exactness contract,
    not a tolerance one: the kernel adds in the model dtype and duplicates
    ``_apply_norm`` op-for-op, so jit'd kernel (interpret) and jit'd ref
    must agree exactly — this is what lets the engine's ``fused_decode``
    flag promise token-identical streams."""
    y = jax.random.normal(jax.random.key(0), shape, dtype)
    x = jax.random.normal(jax.random.key(1), shape, dtype)
    scale = jnp.linspace(0.8, 1.2, shape[-1]).astype(jnp.float32)
    bias = None if kind == "rmsnorm" \
        else jnp.linspace(-0.1, 0.1, shape[-1]).astype(jnp.float32)
    hk, xk = jax.jit(lambda y, x: ln_kernel.decode_residual_norm(
        y, x, scale, bias, kind=kind, interpret=True))(y, x)
    hr, xr = jax.jit(lambda y, x: ln_ref.decode_residual_norm(
        y, x, scale, bias, kind=kind))(y, x)
    assert jnp.array_equal(xk, xr), "fused residual add is not bit-exact"
    assert jnp.array_equal(hk, hr), "fused norm output is not bit-exact"


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 128), (64, 256)])
def test_gated_rmsnorm_kernel_bitwise(shape, dtype):
    """Same bit-exactness contract for the mamba epilogue's SiLU-gated
    RMSNorm (``models.ssm`` delegates to the ref — the kernel must match
    it exactly for the ssm families' fused decode)."""
    y = jax.random.normal(jax.random.key(2), shape, dtype)
    z = jax.random.normal(jax.random.key(3), shape, dtype)
    scale = jnp.linspace(0.9, 1.1, shape[-1]).astype(jnp.float32)
    ok = jax.jit(lambda y, z: ln_kernel.gated_rmsnorm(
        y, z, scale, interpret=True))(y, z)
    orf = jax.jit(lambda y, z: ln_ref.gated_rmsnorm(y, z, scale))(y, z)
    assert jnp.array_equal(ok, orf)


# ------------------------------------------------ fused training/prefill blocks


@pytest.mark.parametrize("name", ["bert-large", "llama3.2-3b",
                                  "jamba-v0.1-52b"])
def test_fused_blocks_tolerance_parity(name):
    """REPRO_FUSED_BLOCKS routes apply_block's residual+norm (and the gelu
    MLP's bias+activation) through the fused kernels. Unlike fused decode
    this is a tolerance contract — the training fusion adds in fp32 where
    the unfused block adds in model dtype — so forward logits must agree
    to rounding, not bitwise. bert-large covers the post-norm
    ``fused_residual_layernorm`` sites (the paper's Fig-13 pattern) and
    ``bias_gelu``; llama covers the pre-norm mixer-add + ln2 fusion; jamba
    the hybrid mamba/attn periods."""
    from repro.configs import smoke_config
    from repro.models import build_model
    arch = smoke_config(name)
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(11)
    batch = {"tokens": jnp.asarray(rng.integers(5, arch.vocab_size, (2, 16)))}

    def fwd(flag, monkey=pytest.MonkeyPatch()):
        monkey.setenv("REPRO_FUSED_BLOCKS", flag)
        try:
            logits, _ = jax.jit(model.forward)(params, batch)
        finally:
            monkey.undo()
        return np.asarray(logits, np.float32)

    ref, fused = fwd("0"), fwd("1")
    np.testing.assert_allclose(fused, ref, atol=3e-2, rtol=1e-2)


def test_fused_blocks_default_off(monkeypatch):
    from repro.models.transformer import fused_blocks_enabled
    monkeypatch.delenv("REPRO_FUSED_BLOCKS", raising=False)
    assert fused_blocks_enabled() is False
    monkeypatch.setenv("REPRO_FUSED_BLOCKS", "1")
    assert fused_blocks_enabled() is True


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(4, 128, 128), (2, 256, 64)])
def test_softmax_kernel(shape, causal):
    s = jax.random.normal(jax.random.key(3), shape, jnp.float32)
    yk = sm_kernel.scale_mask_softmax(s, scale=0.125, causal=causal,
                                      interpret=True)
    yr = sm_ref.scale_mask_softmax(s, scale=0.125, causal=causal)
    np.testing.assert_allclose(yk, yr, atol=1e-6)
    rows = np.asarray(yk.sum(-1))
    np.testing.assert_allclose(rows, np.ones_like(rows), atol=1e-5)


if st is not None:
    @settings(max_examples=8, deadline=None)
    @given(rows=st.sampled_from([1, 3, 8]),
           f=st.sampled_from([64, 256, 2048]),
           seed=st.integers(0, 100))
    def test_lamb_kernel_property_sweep(rows, f, seed):
        ks = jax.random.split(jax.random.key(seed), 4)
        w = jax.random.normal(ks[0], (rows, f), jnp.float32)
        g = jax.random.normal(ks[1], (rows, f), jnp.float32)
        m = jax.random.normal(ks[2], (rows, f), jnp.float32) * 0.1
        v = jnp.abs(jax.random.normal(ks[3], (rows, f))) * 0.01
        kw = dict(ginv=0.3, c1=1.5, c2=1.2, beta1=0.9, beta2=0.999, eps=1e-6,
                  weight_decay=0.01, lr=3e-4)
        outk = lamb_ops.lamb_stage12(w, g, m, v, interpret=True, **kw)
        outr = lamb_ref.lamb_stage12(w, g, m, v, red_axes=(-1,), **kw)
        for a, b in zip(outk, outr):
            np.testing.assert_allclose(a, b, atol=2e-5, rtol=1e-4)
else:
    def test_lamb_kernel_property_sweep():
        pytest.importorskip("hypothesis")
