"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    given = settings = st = None

from repro.kernels.bias_gelu import kernel as bg_kernel, ref as bg_ref
from repro.kernels.fused_lamb import ops as lamb_ops, ref as lamb_ref
from repro.kernels.fused_layernorm import kernel as ln_kernel, ref as ln_ref
from repro.kernels.fused_softmax import kernel as sm_kernel, ref as sm_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(256, 128), (512, 384), (1024, 1024)])
@pytest.mark.parametrize("rms", [True, False])
def test_layernorm_kernel_sweep(shape, dtype, rms):
    x = jax.random.normal(jax.random.key(0), shape, dtype)
    res = jax.random.normal(jax.random.key(1), shape, dtype)
    scale = jnp.ones((shape[-1],)) * 1.1
    bias = None if rms else jnp.full((shape[-1],), 0.05)
    yk = ln_kernel.fused_residual_layernorm(x, res, scale, bias, rms=rms,
                                            interpret=True)
    yr = ln_ref.fused_residual_layernorm(x, res, scale, bias, rms=rms)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32), atol=atol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_bias", [True, False])
def test_bias_gelu_kernel(dtype, with_bias):
    x = jax.random.normal(jax.random.key(2), (512, 256), dtype)
    b = jnp.linspace(-1, 1, 256).astype(dtype) if with_bias else None
    yk = bg_kernel.bias_gelu(x, b, interpret=True)
    yr = bg_ref.bias_gelu(x, b)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32), atol=atol)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(4, 128, 128), (2, 256, 64)])
def test_softmax_kernel(shape, causal):
    s = jax.random.normal(jax.random.key(3), shape, jnp.float32)
    yk = sm_kernel.scale_mask_softmax(s, scale=0.125, causal=causal,
                                      interpret=True)
    yr = sm_ref.scale_mask_softmax(s, scale=0.125, causal=causal)
    np.testing.assert_allclose(yk, yr, atol=1e-6)
    rows = np.asarray(yk.sum(-1))
    np.testing.assert_allclose(rows, np.ones_like(rows), atol=1e-5)


if st is not None:
    @settings(max_examples=8, deadline=None)
    @given(rows=st.sampled_from([1, 3, 8]),
           f=st.sampled_from([64, 256, 2048]),
           seed=st.integers(0, 100))
    def test_lamb_kernel_property_sweep(rows, f, seed):
        ks = jax.random.split(jax.random.key(seed), 4)
        w = jax.random.normal(ks[0], (rows, f), jnp.float32)
        g = jax.random.normal(ks[1], (rows, f), jnp.float32)
        m = jax.random.normal(ks[2], (rows, f), jnp.float32) * 0.1
        v = jnp.abs(jax.random.normal(ks[3], (rows, f))) * 0.01
        kw = dict(ginv=0.3, c1=1.5, c2=1.2, beta1=0.9, beta2=0.999, eps=1e-6,
                  weight_decay=0.01, lr=3e-4)
        outk = lamb_ops.lamb_stage12(w, g, m, v, interpret=True, **kw)
        outr = lamb_ref.lamb_stage12(w, g, m, v, red_axes=(-1,), **kw)
        for a, b in zip(outk, outr):
            np.testing.assert_allclose(a, b, atol=2e-5, rtol=1e-4)
else:
    def test_lamb_kernel_property_sweep():
        pytest.importorskip("hypothesis")
