"""Fused decode residual stream + streaming LM-head epilogue.

Three layers of acceptance:

1. Epilogue correctness — ``kernels.fused_lm_head`` defines the canonical
   inverse-CDF draw ONCE (``ref.head_epilogue`` on materialized logits);
   the vocab-streaming jnp path and the Pallas kernel (interpret mode on
   CPU) must reproduce it BIT-for-bit on the edge cases that historically
   break samplers: fully-masked (all ``-inf``) rows, rows holding ``-inf``
   entries, ``top_p == 1.0``, ``top_k >= V``, and kth-value ties that
   straddle a vocab-tile boundary.

2. Memory shape — the streaming path's compiled HLO must never allocate an
   ``f32 [S, V]`` logits buffer (that buffer's absence IS the optimization);
   the materializing oracle is the positive control proving the assertion
   can fail. This is asserted on the STREAMING implementation's graph: on
   CPU the engine intentionally serves the materializing fallback (an
   op-identical graph is the only way XLA CPU reproduces the unfused
   reduction lowerings bit-for-bit — see ``engine._fused_head``), so the
   engine's own CPU HLO is out of scope here by design.

3. Engine invisibility — ``fused_decode=True`` must emit token streams
   bit-identical to the unfused engine for every servable family, at
   decode horizon N=1 and N=4, across forced-preemption replay, and under
   tp=2 — plus the construction-time gates (post-norm stacks, MLM heads,
   non-tile-aligned TP vocab shards) that fall back with a recorded reason.
"""
import dataclasses
import subprocess
import sys
from functools import lru_cache
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    given = settings = st = None

from repro.analysis.recompile import FAMILY_ARCHS
from repro.configs import smoke_config
from repro.kernels.fused_lm_head import kernel as head_kernel
from repro.kernels.fused_lm_head import ops as head_ops
from repro.kernels.fused_lm_head import ref as head_ref
from repro.models import build_model
from repro.serving import ContinuousEngine, Request
from repro.serving.sampling import SamplingParams, fused_decode_enabled

ROOT = Path(__file__).resolve().parents[1]


# --------------------------------------------------- epilogue: pinned edges ----

def _epilogue_ref(logits, rs, temps, tk, tp):
    return jax.jit(lambda *a: head_ref.head_epilogue(
        *a, sampled=True, filtered=True))(logits, rs, temps, tk, tp)


def test_epilogue_fully_masked_row_draws_token_zero():
    """All-(-inf) row: zero total mass, the prefix walk never fires, and the
    canonical draw's deterministic fallback is token 0 (ref docstring step
    6); the finite probe must report the row bad."""
    v = 256
    logits = jnp.stack([
        jnp.full((v,), -jnp.inf, jnp.float32),            # fully masked
        jnp.linspace(-1, 1, v, dtype=jnp.float32),        # healthy control
    ])
    rs = jnp.asarray([0.7, 0.3], jnp.float32)
    temps = jnp.asarray([1.0, 1.0], jnp.float32)
    tk = jnp.asarray([0, 0], jnp.int32)
    tp = jnp.asarray([1.0, 1.0], jnp.float32)
    tok, ok = _epilogue_ref(logits, rs, temps, tk, tp)
    assert int(tok[0]) == 0
    assert not bool(ok[0]) and bool(ok[1])


def test_epilogue_neg_inf_entries_carry_zero_mass():
    """Rows holding -inf entries: the probe flags them, but the draw is
    still well-defined — masked entries carry exp(-inf) = 0 mass so no
    uniform can ever land on one."""
    v = 256
    rng = np.random.default_rng(3)
    base = rng.normal(size=(4, v)).astype(np.float32)
    masked = rng.random(size=(4, v)) < 0.5
    masked[:, 7] = False                       # keep at least one live lane
    base[masked] = -np.inf
    logits = jnp.asarray(base)
    rs = jnp.asarray(rng.random(4), jnp.float32)
    temps = jnp.full((4,), 0.9, jnp.float32)
    tok, ok = _epilogue_ref(logits, rs, temps,
                            jnp.zeros((4,), jnp.int32),
                            jnp.ones((4,), jnp.float32))
    assert not bool(ok.any())
    for r in range(4):
        assert not masked[r, int(tok[r])], f"row {r} drew a masked lane"


def test_epilogue_top_p_one_and_top_k_ge_v_filter_nothing():
    """top_p == 1.0 and top_k >= V are the no-op corners of the filter: the
    filtered draw must equal the unfiltered draw bitwise."""
    v = 384
    logits = jax.random.normal(jax.random.key(5), (3, v), jnp.float32)
    rs = jnp.asarray([0.11, 0.52, 0.93], jnp.float32)
    temps = jnp.asarray([0.7, 1.0, 1.3], jnp.float32)
    tok_f, ok_f = _epilogue_ref(
        logits, rs, temps,
        jnp.asarray([v, v + 7, 0], jnp.int32),          # >= V or disabled
        jnp.ones((3,), jnp.float32))                    # exactly 1.0
    tok_u, ok_u = jax.jit(lambda *a: head_ref.head_epilogue(
        *a, sampled=True, filtered=False))(
        logits, rs, temps, jnp.zeros((3,), jnp.int32),
        jnp.ones((3,), jnp.float32))
    assert jnp.array_equal(tok_f, tok_u) and jnp.array_equal(ok_f, ok_u)


# ---------------------------------------- epilogue: three-way implementations --

def _threeway(x, w, rs, temps, tk, tp, *, sampled=True, filtered=True,
              softcap=None):
    """(oracle, streaming-jnp, Pallas-interpret) under jit — every
    comparison in this file is jit-vs-jit (eager CPU constant-folds float
    reductions differently, a known 1-ulp hazard unrelated to the fusion)."""
    def oracle(x, w, rs, temps, tk, tp):
        lg = (x @ w.astype(x.dtype)).astype(jnp.float32)
        if softcap:
            lg = softcap * jnp.tanh(lg / softcap)
        return head_ref.head_epilogue(lg, rs, temps, tk, tp,
                                      sampled=sampled, filtered=filtered)

    def stream(x, w, rs, temps, tk, tp):
        return head_ops._head_tokens_jnp(x, w, rs, temps, tk, tp,
                                         sampled=sampled, filtered=filtered,
                                         softcap=softcap, axis_name=None,
                                         tp=1)

    def pallas(x, w, rs, temps, tk, tp):
        return head_kernel.head_tokens(x, w, rs, temps, tk, tp,
                                       sampled=sampled, filtered=filtered,
                                       softcap=softcap, interpret=True)

    args = (x, w, rs, temps, tk, tp)
    return (jax.jit(oracle)(*args), jax.jit(stream)(*args),
            jax.jit(pallas)(*args))


def _assert_threeway_equal(x, w, rs, temps, tk, tp, **kw):
    (t0, k0), (t1, k1), (t2, k2) = _threeway(x, w, rs, temps, tk, tp, **kw)
    assert jnp.array_equal(t0, t1), "streaming tokens diverged from oracle"
    assert jnp.array_equal(t0, t2), "pallas tokens diverged from oracle"
    assert jnp.array_equal(k0, k1) and jnp.array_equal(k0, k2), \
        "finite probes diverged"
    return t0


def test_threeway_kth_value_ties_across_tile_boundary():
    """A run of identical logits straddling both the RED_TILE (128) and the
    GEMM-tile boundary, with top_k cutting inside the run: the count-based
    bisection keeps ALL tied lanes (>= kth survives — same contract as the
    fused_sampling filter), and all three implementations must agree on
    which lane the draw lands on. V=640 streams five 128-wide GEMM tiles,
    so the tie at 126..130 crosses a real tile edge. Identity weights make
    the GEMM inject the crafted logits exactly."""
    v = 640
    assert head_ref.gemm_tile(v) == 128
    rng = np.random.default_rng(9)
    base = rng.normal(scale=0.1, size=(6, v)).astype(np.float32)
    base[:, 126:131] = 3.0                     # 5-way tie across the edge
    base[:, 255:258] = 2.5                     # second tie at the next edge
    x = jnp.asarray(base)
    w = jnp.eye(v, dtype=jnp.float32)
    rs = jnp.asarray(rng.random(6), jnp.float32)
    temps = jnp.asarray([1.0, 0.8, 1.0, 0.0, 1.2, 1.0], jnp.float32)
    tk = jnp.asarray([3, 2, 6, 4, 1, 7], jnp.int32)    # cuts inside the ties
    tp = jnp.asarray([1.0, 0.95, 0.9, 1.0, 1.0, 0.8], jnp.float32)
    tok = _assert_threeway_equal(x, w, rs, temps, tk, tp)
    # top_k=1 with a 5-way tie keeps the whole tie class; the greedy row
    # (temps == 0) must take the FIRST tied lane
    assert int(tok[3]) == 126


def test_threeway_pinned_param_corners():
    """Pinned corners through real (non-identity) weights: greedy rows mixed
    with sampled, top_p exactly 1.0, top_k >= V, top_k == 1, bf16 hidden,
    and a softcap — all three implementations bit-agree."""
    s, d, v = 5, 64, 384
    x = jax.random.normal(jax.random.key(0), (s, d), jnp.bfloat16)
    w = (jax.random.normal(jax.random.key(1), (d, v), jnp.float32)
         * 0.1).astype(jnp.bfloat16)
    rs = jnp.asarray([0.01, 0.5, 0.99, 0.33, 0.66], jnp.float32)
    temps = jnp.asarray([0.0, 1.0, 0.7, 1.5, 1.0], jnp.float32)
    tk = jnp.asarray([0, v + 3, 1, 8, 0], jnp.int32)
    tp = jnp.asarray([1.0, 1.0, 0.9, 0.5, 1.0], jnp.float32)
    _assert_threeway_equal(x, w, rs, temps, tk, tp)
    _assert_threeway_equal(x, w, rs, temps, tk, tp, softcap=30.0)
    _assert_threeway_equal(x, w, rs, temps, tk, tp, sampled=False)
    _assert_threeway_equal(x, w, rs, temps, tk, tp, filtered=False)


if st is not None:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000),
           v=st.sampled_from([256, 384, 512, 640]),
           dtype=st.sampled_from(["float32", "bfloat16"]))
    def test_threeway_property_sweep(seed, v, dtype):
        s, d = 4, 32
        ks = jax.random.split(jax.random.key(seed), 6)
        dt = jnp.dtype(dtype)
        x = jax.random.normal(ks[0], (s, d), dt)
        w = (jax.random.normal(ks[1], (d, v), jnp.float32) * 0.2).astype(dt)
        rs = jax.random.uniform(ks[2], (s,), jnp.float32)
        temps = jax.random.uniform(ks[3], (s,), jnp.float32, 0.0, 1.5)
        tk = jax.random.randint(ks[4], (s,), 0, v + 2)
        tp = jax.random.uniform(ks[5], (s,), jnp.float32, 0.1, 1.0)
        _assert_threeway_equal(x, w, rs, temps, tk, tp)
else:
    def test_threeway_property_sweep():
        pytest.importorskip("hypothesis")


# ------------------------------------------------- no [S, V] buffer in HLO ----

def test_streaming_hlo_never_holds_logits_row():
    """The whole point of the streaming epilogue: its optimized HLO holds no
    f32 [S, V] tensor. The materializing oracle is the positive control —
    the same shape string MUST appear there, proving the probe detects what
    it claims to rule out. (S=4 is chosen so the [S, V] shape string cannot
    collide with the [D, V] weight, D=64.)"""
    s, d, v = 4, 64, 1024
    x = jax.random.normal(jax.random.key(0), (s, d), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (d, v), jnp.float32)
    rs = jnp.full((s,), 0.5, jnp.float32)
    temps = jnp.full((s,), 1.0, jnp.float32)
    tk = jnp.full((s,), 8, jnp.int32)
    tp = jnp.full((s,), 0.9, jnp.float32)
    needle = f"f32[{s},{v}]"

    def stream(x, w, rs, temps, tk, tp):
        return head_ops._head_tokens_jnp(x, w, rs, temps, tk, tp,
                                         sampled=True, filtered=True,
                                         softcap=None, axis_name=None, tp=1)

    def materialize(x, w, rs, temps, tk, tp):
        lg = (x @ w).astype(jnp.float32)
        return head_ref.head_epilogue(lg, rs, temps, tk, tp,
                                      sampled=True, filtered=True)

    args = (x, w, rs, temps, tk, tp)
    hlo_stream = jax.jit(stream).lower(*args).compile().as_text()
    hlo_mat = jax.jit(materialize).lower(*args).compile().as_text()
    assert needle in hlo_mat, \
        "positive control lost its logits buffer — probe is meaningless"
    assert needle not in hlo_stream, \
        f"streaming epilogue materialized a {needle} logits buffer"
    # and the two graphs still agree on the tokens they emit
    t_s, k_s = jax.jit(stream)(*args)
    t_m, k_m = jax.jit(materialize)(*args)
    assert jnp.array_equal(t_s, t_m) and jnp.array_equal(k_s, k_m)


# ------------------------------------------------------- engine bit-parity ----

@lru_cache(maxsize=None)
def _smoke_model(name):
    arch = smoke_config(name)
    model = build_model(arch)
    return arch, model, model.init(jax.random.key(0))


def _requests(arch, n=5, seed=7):
    """Mixed greedy / seeded-sampled / filtered traffic, ragged lengths."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        prompt = list(map(int, rng.integers(5, arch.vocab_size,
                                            int(rng.integers(6, 18)))))
        sp = (SamplingParams(),
              SamplingParams(temperature=0.8, seed=100 + i),
              SamplingParams(temperature=0.9, top_k=8, top_p=0.9,
                             seed=200 + i))[i % 3]
        reqs.append(Request(uid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(4, 9)),
                            sampling=sp))
    return reqs


def _serve(model, params, reqs, **kw):
    defaults = dict(num_slots=3, num_pages=64, page_size=4, max_seq_len=64,
                    prefix_cache=False, sanitize=True)
    defaults.update(kw)
    engine = ContinuousEngine(model, params, **defaults)
    res = engine.run(list(reqs))
    return engine, {uid: r["tokens"] for uid, r in res.items()}


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_fused_decode_bit_parity_all_families(family):
    """fused_decode=True streams bit-identical to the unfused engine for
    every servable family, at decode horizon N=1 and N=4, on mixed
    greedy/sampled/filtered traffic with the sanitizer on. This is the bit
    contract (not tolerance): the fused residual stream keeps every add at
    the same graph position as the unfused stack, so even bf16 smoke
    models must not flip a single draw."""
    arch, model, params = _smoke_model(FAMILY_ARCHS[family])
    reqs = _requests(arch)
    e_ref, ref = _serve(model, params, reqs, decode_steps=1,
                        fused_decode=False)
    assert e_ref.fused_decode is False
    for n in (1, 4):
        e, toks = _serve(model, params, reqs, decode_steps=n,
                         fused_decode=True)
        assert e.fused_decode, e.fused_decode_off_reason
        assert toks == ref, f"{family} fused decode diverged at N={n}"


def test_fused_decode_preemption_replay_parity():
    """A forced preemption mid-stream under the fused multi-step engine must
    replay token-identically vs an unpreempted unfused N=1 run: the forced
    replay re-derives every PRNG key from the stream position, and the
    fused head derives the same ``rs`` uniforms from the same keys."""
    arch, model, params = _smoke_model("llama3.2-3b")
    reqs = [dataclasses.replace(r, max_new_tokens=8)
            for r in _requests(arch, seed=29)]
    _, ref = _serve(model, params, reqs, decode_steps=1, fused_decode=False)
    engine = ContinuousEngine(model, params, num_slots=3, num_pages=64,
                              page_size=4, max_seq_len=64, prefix_cache=False,
                              sanitize=True, decode_steps=4,
                              fused_decode=True)
    sched = engine.scheduler
    orig = sched.ensure_capacity
    fired = []

    def forced():
        out = orig()
        victim = next((s for s in sched.running.values()
                       if s.request.uid == 1), None)
        if not fired and victim is not None and not victim.done \
                and len(sched.running) > 1 and len(victim.generated) >= 3:
            sched._preempt(victim)
            out.append(victim)
            fired.append(victim.request.uid)
        return out

    sched.ensure_capacity = forced
    res = engine.run(list(reqs))
    assert fired == [1], "forced preemption must actually fire"
    assert {u: r["tokens"] for u, r in res.items()} == ref, \
        "preempted fused multi-step stream diverged from unfused N=1"


# ------------------------------------------------------------------ tp = 2 ----

def _run_subprocess(body: str):
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'\n"
        "os.environ['JAX_PLATFORMS'] = 'cpu'\n" + body)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=ROOT, timeout=500,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    return r.stdout


def test_tp2_fused_decode_parity_and_shard_gate():
    """tp=2 with fused decode streams token-identical to the unfused tp=1
    engine (stats combine across shards, never logits), and a vocab whose
    per-shard slice misses the 128-wide reduction tile falls back with the
    recorded off-reason instead of serving wrong."""
    out = _run_subprocess(r"""
import dataclasses
import jax, numpy as np
from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import ContinuousEngine, Request
from repro.serving.sampling import SamplingParams

arch = dataclasses.replace(smoke_config("llama3.2-3b"), num_kv_heads=4,
                           dtype="float32", param_dtype="float32")
model = build_model(arch)
params = model.init(jax.random.key(0))
rng = np.random.default_rng(7)
reqs = [Request(uid=i,
                prompt=list(map(int, rng.integers(5, arch.vocab_size, 10))),
                max_new_tokens=6,
                sampling=(SamplingParams() if i % 2 == 0 else
                          SamplingParams(temperature=0.8, top_k=12,
                                         top_p=0.9, seed=100 + i)))
        for i in range(4)]

def serve(**kw):
    eng = ContinuousEngine(model, params, num_slots=3, num_pages=64,
                           page_size=8, max_seq_len=64, prefix_cache=False,
                           **kw)
    res = eng.run(list(reqs))
    return eng, {u: r["tokens"] for u, r in res.items()}

_, ref = serve(fused_decode=False)
for tp in (1, 2):
    eng, toks = serve(tp=tp, fused_decode=True)
    assert eng.fused_decode, (tp, eng.fused_decode_off_reason)
    assert toks == ref, (tp, toks, ref)

# shard-width gate: pad_vocab(384) = 384, 384/2 = 192 is not a whole
# number of 128-wide reduction tiles -> fused decode off, reason recorded
arch2 = dataclasses.replace(arch, vocab_size=384)
model2 = build_model(arch2)
params2 = model2.init(jax.random.key(0))
eng2 = ContinuousEngine(model2, params2, num_slots=2, num_pages=32,
                        page_size=8, max_seq_len=32, prefix_cache=False,
                        tp=2, fused_decode=True)
assert not eng2.fused_decode
assert "reduction tile" in eng2.fused_decode_off_reason
print("TP-FUSED-OK")
""")
    assert "TP-FUSED-OK" in out


# --------------------------------------------------------- construction gates --

def test_fused_decode_off_reasons():
    """Post-norm stacks and MLM-transform heads must fall back at
    construction with a recorded reason; an explicit fused_decode=False is
    a choice, not a fallback, so no reason is recorded."""
    arch, model, params = _smoke_model("llama3.2-3b")
    kw = dict(num_slots=2, num_pages=32, page_size=4, max_seq_len=32,
              prefix_cache=False)

    post = dataclasses.replace(arch, post_norm=True)
    mpost = build_model(post)
    e = ContinuousEngine(mpost, mpost.init(jax.random.key(0)), **kw)
    assert not e.fused_decode
    assert "pre-norm" in e.fused_decode_off_reason

    mlm = dataclasses.replace(arch, mlm_transform=True)
    mmlm = build_model(mlm)
    e = ContinuousEngine(mmlm, mmlm.init(jax.random.key(0)), **kw)
    assert not e.fused_decode
    assert "MLM" in e.fused_decode_off_reason

    e = ContinuousEngine(model, params, fused_decode=False, **kw)
    assert not e.fused_decode and e.fused_decode_off_reason is None

    e = ContinuousEngine(model, params, fused_decode=True, **kw)
    assert e.fused_decode and e.fused_decode_off_reason is None


def test_fused_decode_env_default(monkeypatch):
    """REPRO_FUSED_DECODE drives the engine default (unset = on); an
    explicit ctor flag beats the env."""
    monkeypatch.delenv("REPRO_FUSED_DECODE", raising=False)
    assert fused_decode_enabled() is True
    monkeypatch.setenv("REPRO_FUSED_DECODE", "0")
    assert fused_decode_enabled() is False

    arch, model, params = _smoke_model("llama3.2-3b")
    kw = dict(num_slots=2, num_pages=32, page_size=4, max_seq_len=32,
              prefix_cache=False)
    e = ContinuousEngine(model, params, **kw)
    assert not e.fused_decode and e.fused_decode_off_reason is None
    e = ContinuousEngine(model, params, fused_decode=True, **kw)
    assert e.fused_decode
    monkeypatch.setenv("REPRO_FUSED_DECODE", "1")
    assert fused_decode_enabled() is True


def test_tp_fusable_predicate():
    rt = head_ops.RED_TILE
    assert head_ops.tp_fusable(8 * rt, 1)
    assert head_ops.tp_fusable(8 * rt, 2)
    assert head_ops.tp_fusable(8 * rt, 4)
    assert not head_ops.tp_fusable(8 * rt, 3)      # does not divide
    assert not head_ops.tp_fusable(3 * rt, 2)      # slice misses the tile
    assert head_ops.tp_fusable(3 * rt, 3)


# --------------------------------------------------------------- serve CLI ----

def test_serve_cli_fused_decode_flag(capsys):
    from repro.launch import serve
    with pytest.raises(SystemExit):
        serve.main(["--arch", "llama3.2-3b", "--smoke", "--engine", "static",
                    "--fused-decode"])
    assert "requires --engine continuous" in capsys.readouterr().err
    out = serve.main(["--arch", "llama3.2-3b", "--smoke", "--engine",
                      "continuous", "--batch", "2", "--prompt-len", "8",
                      "--gen-len", "3", "--no-fused-decode"])
    assert out["fused_decode"] is False
    assert out["fused_decode_off_reason"] is None
