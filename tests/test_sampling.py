"""Per-request sampling (temperature / top-k / top-p / seed) and the
forced-replay preemption invariant.

The contract under test: the token a request emits at stream position p
depends only on (its seed, p, the logits) — never on the decode slot, the
co-batched neighbours, the engine variant, or whether the sequence was
preempted and resumed in between. At temperature 0 the sampler must be
bit-identical to the historical greedy argmax path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    given = settings = st = None

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import (ContinuousEngine, Request, SamplingParams,
                           sample_tokens)


# ------------------------------------------------------------- sampler units ----

def _arrs(rows, seed=0, pos=0, temp=1.0, top_k=0, top_p=1.0):
    """Broadcast scalar params to per-row sampler arrays."""
    def vec(v, dt):
        a = np.asarray(v, dt)
        return jnp.asarray(np.broadcast_to(a, (rows,)))
    return (vec(seed, np.uint32), vec(pos, np.int32),
            vec(temp, np.float32), vec(top_k, np.int32),
            vec(top_p, np.float32))


def test_temperature_zero_is_bitwise_argmax():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(5, 97)).astype(np.float32))
    toks = sample_tokens(logits, *_arrs(5, seed=range(5), temp=0.0))
    assert (np.asarray(toks) == np.argmax(np.asarray(logits), -1)).all()


def test_top_k_one_is_argmax_at_any_temperature():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    for temp in (0.5, 1.0, 3.0):
        toks = sample_tokens(logits, *_arrs(4, seed=range(4), pos=7,
                                            temp=temp, top_k=1))
        assert (np.asarray(toks) == np.argmax(np.asarray(logits), -1)).all()


def test_top_k_restricts_to_candidate_set():
    rng = np.random.default_rng(2)
    logits_np = rng.normal(size=(1, 50)).astype(np.float32)
    logits = jnp.asarray(logits_np)
    k = 5
    top = set(np.argsort(logits_np[0])[-k:])
    drawn = set()
    for pos in range(40):
        toks = sample_tokens(logits, *_arrs(1, seed=9, pos=pos, temp=1.5,
                                            top_k=k))
        drawn.add(int(toks[0]))
    assert drawn <= top
    assert len(drawn) > 1                      # actually stochastic


def test_top_p_restricts_to_nucleus():
    rng = np.random.default_rng(3)
    logits_np = rng.normal(size=(1, 50)).astype(np.float32)
    logits_np[0, 7] += 6.0                     # ~dominant token
    probs = np.exp(logits_np[0] - logits_np[0].max())
    probs /= probs.sum()
    order = np.argsort(probs)[::-1]
    nucleus = set(order[:np.searchsorted(np.cumsum(probs[order]), 0.9) + 1])
    logits = jnp.asarray(logits_np)
    for pos in range(40):
        toks = sample_tokens(logits, *_arrs(1, seed=4, pos=pos, temp=1.0,
                                            top_p=0.9))
        assert int(toks[0]) in nucleus


def test_draw_is_independent_of_slot_and_neighbours():
    """The same (seed, position, logits row) must yield the same token in
    any slot of any batch composition — the property that keeps continuous
    batching out of the sampling semantics."""
    rng = np.random.default_rng(4)
    row = rng.normal(size=(73,)).astype(np.float32)
    expect = None
    for slot, batch in ((0, 1), (2, 4), (5, 8)):
        noise = rng.normal(size=(batch, 73)).astype(np.float32)
        noise[slot] = row
        seeds = rng.integers(0, 2 ** 31, batch).astype(np.uint32)
        seeds[slot] = 11
        toks = sample_tokens(
            jnp.asarray(noise), jnp.asarray(seeds),
            jnp.full((batch,), 6, jnp.int32),
            jnp.full((batch,), 0.9, jnp.float32),
            jnp.full((batch,), 0, jnp.int32),
            jnp.full((batch,), 0.95, jnp.float32))
        tok = int(toks[slot])
        if expect is None:
            expect = tok
        assert tok == expect


def test_positions_decorrelate_draws():
    rng = np.random.default_rng(5)
    logits = jnp.asarray(rng.normal(size=(1, 200)).astype(np.float32))
    toks = {int(sample_tokens(logits, *_arrs(1, seed=3, pos=p, temp=2.0))[0])
            for p in range(30)}
    assert len(toks) > 5                       # key actually folds position


def test_sampling_params_validation():
    for bad in (dict(temperature=-0.1), dict(top_k=-1), dict(top_p=0.0),
                dict(top_p=1.5), dict(seed=-1), dict(seed=2 ** 32)):
        with pytest.raises(ValueError):
            SamplingParams(**bad)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.7).greedy


# ----------------------------------------------------------------- e2e helpers --

@pytest.fixture(scope="module")
def fp32_llama():
    arch = smoke_config("llama3.2-3b")
    arch = dataclasses.replace(arch, dtype="float32", param_dtype="float32")
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    return arch, model, params


def _static_sampled(model, params, prompts, gens, sps):
    """Per-request static decode (batch 1) through the shared sampler: the
    reference stream every engine variant must reproduce draw for draw."""
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)
    sample = jax.jit(sample_tokens)

    def draw(logits, sp, pos):
        return int(sample(logits,
                          jnp.asarray([sp.seed], jnp.uint32),
                          jnp.asarray([pos], jnp.int32),
                          jnp.asarray([sp.temperature], jnp.float32),
                          jnp.asarray([sp.top_k], jnp.int32),
                          jnp.asarray([sp.top_p], jnp.float32))[0])

    out = []
    for prompt, glen, sp in zip(prompts, gens, sps):
        plen = len(prompt)
        caches = model.init_caches(None, 1, plen + glen)
        logits, caches = prefill(params, caches,
                                 {"tokens": jnp.asarray([prompt])})
        tok = draw(logits[:, -1], sp, plen)
        ids = [tok]
        for s in range(glen - 1):
            logits, caches = decode(
                params, caches,
                {"tokens": jnp.asarray([[tok]]),
                 "positions": jnp.full((1,), plen + s, jnp.int32)})
            tok = draw(logits[:, -1], sp, plen + 1 + s)
            ids.append(tok)
        out.append(ids)
    return out


def _mixed_requests(arch, rng, n=4, share_prefix=False):
    """Requests mixing greedy and sampled settings with distinct seeds."""
    shared = list(map(int, rng.integers(5, arch.vocab_size,
                                        int(rng.integers(6, 15)))))
    prompts, gens, sps = [], [], []
    choices = [SamplingParams(),
               SamplingParams(temperature=0.7, seed=0),
               SamplingParams(temperature=1.2, top_k=8, seed=0),
               SamplingParams(temperature=0.9, top_p=0.8, seed=0)]
    for i in range(n):
        own = list(map(int, rng.integers(5, arch.vocab_size,
                                         int(rng.integers(2, 9)))))
        prompts.append((shared + own) if share_prefix else
                       list(map(int, rng.integers(5, arch.vocab_size,
                                                  int(rng.integers(4, 14))))))
        gens.append(int(rng.integers(3, 9)))
        sp = choices[i % len(choices)]
        sps.append(dataclasses.replace(sp, seed=int(rng.integers(2 ** 31))))
    return prompts, gens, sps


def _run_engine(model, params, prompts, gens, sps, *, prefix_cache,
                num_slots=4, num_pages=48, page_size=8, max_seq_len=64, **kw):
    engine = ContinuousEngine(model, params, num_slots=num_slots,
                              num_pages=num_pages, page_size=page_size,
                              max_seq_len=max_seq_len,
                              prefix_cache=prefix_cache, **kw)
    res = engine.run([Request(uid=i, prompt=prompts[i],
                              max_new_tokens=gens[i], sampling=sps[i])
                      for i in range(len(prompts))])
    return engine, [res[i]["tokens"] for i in range(len(prompts))]


# ---------------------------------------------------------- cross-engine parity -

def test_sampled_parity_across_engines(fp32_llama):
    """Fixed per-request seeds: identical tokens across {static, continuous,
    continuous+prefix-cache}, greedy and sampled requests co-batched."""
    arch, model, params = fp32_llama
    rng = np.random.default_rng(31)
    prompts, gens, sps = _mixed_requests(arch, rng, share_prefix=True)
    ref = _static_sampled(model, params, prompts, gens, sps)
    for prefix_cache in (False, True):
        _, toks = _run_engine(model, params, prompts, gens, sps,
                              prefix_cache=prefix_cache)
        assert toks == ref, f"prefix_cache={prefix_cache} diverged"
    # the sampled requests must actually be sampling (greedy row differs)
    greedy_ref = _static_sampled(model, params, prompts, gens,
                                 [SamplingParams()] * len(prompts))
    assert any(r != g for r, g in zip(ref, greedy_ref))


def test_sampled_parity_under_natural_preemption(fp32_llama):
    """A pool too small for every request: recycling and forced-replay
    preemption must not change one sampled token vs the static reference."""
    arch, model, params = fp32_llama
    rng = np.random.default_rng(37)
    prompts = [list(map(int, rng.integers(5, arch.vocab_size, 12)))
               for _ in range(5)]
    gens = [4, 16, 7, 12, 9]
    sps = [SamplingParams(temperature=0.8, top_k=0 if i % 2 else 20,
                          top_p=0.95, seed=1000 + i) for i in range(5)]
    ref = _static_sampled(model, params, prompts, gens, sps)
    engine, toks = _run_engine(model, params, prompts, gens, sps,
                               prefix_cache=False, num_slots=2, num_pages=10,
                               page_size=4, max_seq_len=32)
    assert toks == ref
    assert engine.prefills > 5                 # preemption actually happened


# ------------------------------------------------------- forced-replay property -

def _forced_preempt_engine(model, params, *, uid, when, **kw):
    """Engine whose scheduler force-preempts request ``uid`` once, the first
    time ``when(seq)`` holds (simulated pool pressure, deterministic)."""
    engine = ContinuousEngine(model, params, **kw)
    sched = engine.scheduler
    orig = sched.ensure_capacity
    fired = []

    def forced():
        out = orig()
        victim = next((s for s in sched.running.values()
                       if s.request.uid == uid), None)
        if not fired and victim is not None and not victim.done \
                and len(sched.running) > 1 and when(victim):
            sched._preempt(victim)
            out.append(victim)
            fired.append(victim.request.uid)
        return out

    sched.ensure_capacity = forced
    return engine, fired


def _replay_scenario(fp32_llama, when, *, prefix_cache, seed, page_size=8,
                     prefill_chunk=None, share_prefix=True):
    """Serve the same sampled requests with and without one forced
    preemption of uid 1; both runs must be token-identical (replay
    exactness). Returns the forced engine for extra assertions."""
    arch, model, params = fp32_llama
    rng = np.random.default_rng(seed)
    prompts, gens, sps = _mixed_requests(arch, rng, share_prefix=share_prefix)
    gens = [max(g, 6) for g in gens]           # room for a mid-flight preempt
    kw = dict(num_slots=4, num_pages=48, page_size=page_size, max_seq_len=64,
              prefix_cache=prefix_cache)
    if prefill_chunk is not None:
        kw["prefill_chunk"] = prefill_chunk
    reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=gens[i],
                    sampling=sps[i]) for i in range(len(prompts))]
    _, clean = _run_engine(model, params, prompts, gens, sps, **kw)
    engine, fired = _forced_preempt_engine(model, params, uid=1, when=when,
                                           **kw)
    res = engine.run(reqs)
    assert fired == [1], "forced preemption must actually fire"
    forced = [res[i]["tokens"] for i in range(len(prompts))]
    assert forced == clean, "preempted+resumed stream diverged from " \
                            "the unpreempted run"
    return engine


def test_replay_exact_preemption_mid_decode(fp32_llama):
    _replay_scenario(fp32_llama,
                     lambda seq: len(seq.generated) >= 2,
                     prefix_cache=False, seed=41)


def test_replay_parity_fused_vs_reference_sampler(fp32_llama):
    """The filter implementation (fused bisection kernel vs sort-based
    reference) and a forced mid-decode preemption are BOTH token-invisible:
    an unpreempted fused-sampler run and a preempted+replayed
    reference-sampler run of the same requests emit identical streams."""
    arch, model, params = fp32_llama
    rng = np.random.default_rng(53)
    prompts, gens, sps = _mixed_requests(arch, rng, share_prefix=True)
    gens = [max(g, 6) for g in gens]
    kw = dict(num_slots=4, num_pages=48, page_size=8, max_seq_len=64,
              prefix_cache=False)
    reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=gens[i],
                    sampling=sps[i]) for i in range(len(prompts))]
    _, fused_clean = _run_engine(model, params, prompts, gens, sps,
                                 fused_sampling=True, **kw)
    engine, fired = _forced_preempt_engine(
        model, params, uid=1, when=lambda seq: len(seq.generated) >= 2,
        fused_sampling=False, **kw)
    res = engine.run(reqs)
    assert fired == [1], "forced preemption must actually fire"
    ref_forced = [res[i]["tokens"] for i in range(len(prompts))]
    assert ref_forced == fused_clean, \
        "reference-sampler replay diverged from the fused engine"
    # the reference engine really traced the ref filter variant
    fd = engine.fused_decode
    assert ("decode", True, True, False, fd) in engine._jit_cache
    assert ("decode", True, True, True, fd) not in engine._jit_cache


def test_replay_exact_preemption_mid_prefill(fp32_llama):
    """The preemption lands while the victim is still chunk-prefilling its
    prompt (prefilled < prefill_target): nothing was emitted yet, the whole
    prompt re-prefills, and the stream must still be identical."""
    engine = _replay_scenario(
        fp32_llama, lambda seq: seq.prefilled < seq.prefill_target,
        prefix_cache=True, seed=43, page_size=4, prefill_chunk=4)
    # the interrupted prefill never completed, so completions == admissions:
    # one per request (the victim's count comes from its re-admission)
    assert engine.prefills == 4


def test_replay_exact_preemption_on_cow_tail(fp32_llama):
    """The victim was admitted through a copy-on-write tail page (shared
    prefix not page-aligned). Preempting and resuming it must reproduce the
    identical sampled stream, CoW copy and all."""
    arch, model, params = fp32_llama
    cow_admissions = []

    def instrument(engine):
        orig = engine._start_prefill

        def hook(seq):
            if seq.cow is not None:
                cow_admissions.append(seq.request.uid)
            orig(seq)
        engine._start_prefill = hook

    rng = np.random.default_rng(47)
    system = list(map(int, rng.integers(5, arch.vocab_size, 19)))  # 2x8 + 3
    prompts = [system + list(map(int, rng.integers(5, arch.vocab_size, 4)))
               for _ in range(2)]
    gens = [8, 8]
    sps = [SamplingParams(temperature=0.9, top_p=0.9, seed=7),
           SamplingParams(temperature=0.9, top_p=0.9, seed=8)]
    reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=gens[i],
                    sampling=sps[i]) for i in range(2)]
    kw = dict(num_slots=2, num_pages=48, page_size=8, max_seq_len=64,
              prefix_cache=True)

    clean_engine = ContinuousEngine(model, params, **kw)
    clean = clean_engine.run([dataclasses.replace(r) for r in reqs])
    engine, fired = _forced_preempt_engine(
        model, params, uid=1, when=lambda seq: len(seq.generated) >= 1, **kw)
    instrument(engine)
    res = engine.run(reqs)
    assert fired == [1]
    assert 1 in cow_admissions, "uid 1 must have been admitted via CoW"
    assert engine.cow_copies >= 1
    for i in range(2):
        assert res[i]["tokens"] == clean[i]["tokens"], f"request {i} diverged"


# ------------------------------------------------ property sweep (hypothesis) ---

def _replay_property_case(fp32_llama, seed, page_size, num_pages, slots,
                          share_prefix):
    """Tiny pools (recycling + natural preemption), mixed greedy/sampled
    requests: every engine variant must equal the static sampled reference."""
    arch, model, params = fp32_llama
    rng = np.random.default_rng(seed)
    prompts, gens, sps = _mixed_requests(arch, rng, share_prefix=share_prefix)
    ref = _static_sampled(model, params, prompts, gens, sps)
    for prefix_cache in (False, True):
        engine, toks = _run_engine(model, params, prompts, gens, sps,
                                   prefix_cache=prefix_cache,
                                   num_slots=slots, num_pages=num_pages,
                                   page_size=page_size, max_seq_len=32)
        assert toks == ref, (seed, page_size, num_pages, slots, share_prefix,
                             prefix_cache)
        assert engine.scheduler.cache.live_tokens == 0


if st is not None:
    @settings(max_examples=5, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        page_size=st.sampled_from([4, 8]),
        num_pages=st.integers(10, 18),
        slots=st.sampled_from([2, 3]),
        share_prefix=st.booleans(),
    )
    def test_sampled_parity_property_sweep(fp32_llama, seed, page_size,
                                           num_pages, slots, share_prefix):
        _replay_property_case(fp32_llama, seed, page_size, num_pages, slots,
                              share_prefix)
else:
    def test_sampled_parity_property_sweep():
        pytest.importorskip("hypothesis")


def test_sampled_parity_smoke_without_hypothesis(fp32_llama):
    """One pinned instance of the property (runs even without hypothesis)."""
    _replay_property_case(fp32_llama, seed=4321, page_size=4, num_pages=12,
                          slots=2, share_prefix=True)
