"""Recompilation auditor: closed jit caches for the servable families, a
planted shape-dependent retrace that must fail loudly, and the tp=2 audit
over a real (forced-host) device mesh.

The audits are abstract — ``jax.eval_shape`` only, no kernels execute — so
these tests are cheap despite covering full serving traces.
"""
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.recompile import (FAMILY_ARCHS, AuditEngine, AuditError,
                                      AuditReport, audit_family)
from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import Request

ROOT = Path(__file__).resolve().parents[1]


def _run_subprocess(body: str):
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'\n"
        "os.environ['JAX_PLATFORMS'] = 'cpu'\n" + body)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=ROOT, timeout=500,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    return r.stdout


# ------------------------------------------------------------ closed caches --

def test_dense_cache_closed_and_fully_exercised():
    report = audit_family("dense")      # .check() already ran inside
    kinds = {k[0] for k in report.variants}
    # the starved-pool mixed traffic must reach every step kind the dense
    # engine can build: both prefill finalities, all decode sampling
    # variants, and the CoW tail copy
    assert kinds == {"decode", "prefill", "copy"}
    fd = True   # fused decode is the default where supported
    assert ("prefill", False, False, False, False, fd) in report.variants, \
        "non-final prefill chunk variant never exercised"
    # the filtered variants name their filter implementation (fused by
    # default); unfiltered variants pin the fused element False so they stay
    # shared between fused and reference engines. Every key's trailing
    # element is the engine's fused-decode flag.
    assert ("decode", True, True, True, fd) in report.variants
    assert ("prefill", True, True, True, True, fd) in report.variants
    assert all(len(sigs) == 1 for sigs in report.signatures.values())


def test_dense_reference_sampler_cache_closed():
    """fused_sampling=False audits the sort-based reference filter: same
    variant census, with the fused element of the filtered keys False."""
    report = audit_family("dense", fused_sampling=False)
    fd = True
    assert ("decode", True, True, False, fd) in report.variants
    assert ("prefill", True, True, True, False, fd) in report.variants
    assert ("decode", True, True, True, fd) not in report.variants
    assert all(len(sigs) == 1 for sigs in report.signatures.values())


def test_fused_decode_off_cache_closed():
    """fused_decode=False audits the reference decode/prefill variants: the
    same census with the trailing fd element pinned False — the unfused
    half of the bit-parity contract must keep a closed cache too."""
    report = audit_family("dense", fused_decode=False)
    assert ("decode", True, True, True, False) in report.variants
    assert ("prefill", True, True, True, True, False) in report.variants
    assert not any(k[-1] is True for k in report.variants if k[0] != "copy")
    assert all(len(sigs) == 1 for sigs in report.signatures.values())


def test_fused_decode_multistep_cache_closed_all_families():
    """Every servable family: the fused-decode multi-step loop's
    horizon-keyed variants (('decode', ..., fd, N)) stay closed."""
    for family in sorted(FAMILY_ARCHS):
        report = audit_family(family, decode_steps=4, fused_decode=True)
        assert ("decode", True, True, True, True, 4) in report.variants, \
            (family, report.variants)
        assert all(len(s) == 1 for s in report.signatures.values())


def test_hybrid_cache_closed():
    report = audit_family("hybrid")
    kinds = {k[0] for k in report.variants}
    # hybrid serves with the prefix cache gated off: no copy variant exists
    assert kinds == {"decode", "prefill"}
    assert all(len(sigs) == 1 for sigs in report.signatures.values())


def test_report_summary_names_every_variant():
    report = audit_family("moe")
    s = report.summary()
    assert "moe" in s and "tp=1" in s
    assert f"{len(report.signatures)} variant(s)" in s


# ---------------------------------------------------------- planted retrace --

def _greedy(uid, prompt, n=3):
    return Request(uid=uid, prompt=prompt, max_new_tokens=n)


def test_planted_shape_retrace_is_detected():
    """Mutate the prefill chunk size between traces: the same
    ('prefill', final, ...) variant now sees two chunk widths — exactly the
    silent-retrace bug class the auditor exists to catch."""
    arch = smoke_config(FAMILY_ARCHS["dense"])
    model = build_model(arch)
    engine = AuditEngine(model, model.init(__import__("jax").random.key(0)),
                         num_slots=2, num_pages=16, page_size=4,
                         max_seq_len=48)
    engine.run([_greedy(0, list(range(5, 15)))])        # one 16-wide chunk
    engine.prefill_chunk = 8                            # the planted bug
    engine.run([_greedy(1, list(range(30, 40)))])       # two 8-wide chunks
    report = AuditReport(family="dense", arch=FAMILY_ARCHS["dense"], tp=1,
                         signatures=dict(engine.signatures))
    with pytest.raises(AuditError, match="not closed"):
        report.check()
    # and the census pinpoints the culprit: the final-prefill variant holds
    # two distinct signatures, decode still one
    fd = engine.fused_decode
    final_prefill = engine.signatures[
        ("prefill", True, False, False, False, fd)]
    assert len(final_prefill) == 2
    assert len(engine.signatures[("decode", False, False, False, fd)]) == 1


def test_empty_trace_is_an_audit_failure():
    with pytest.raises(AuditError, match="no engine step"):
        AuditReport(family="dense", arch="x", tp=1, signatures={}).check()


# ------------------------------------------------------------------- tp = 2 --

def test_tp2_caches_closed_over_device_mesh():
    """tp=2 audits shard-map the abstract step over a real 2-device mesh, so
    they run in a subprocess with forced host devices (the pattern
    ``test_tp_serving.py`` established)."""
    out = _run_subprocess(r"""
from repro.analysis.recompile import audit_family
for family in ("dense", "hybrid"):
    for fd in (True, False):
        report = audit_family(family, tp=2, fused_decode=fd)
        print("closed", family, fd, len(report.signatures))
print("AUDIT_TP2_OK")
""")
    assert "AUDIT_TP2_OK" in out
    assert out.count("closed") == 4
