"""Mamba-2 SSD: chunked == sequential recurrence; decode == prefill; hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    given = settings = st = None

from repro.models import ssm


def ssd_sequential(x, dt, a, b, c):
    """O(S) reference recurrence: h_t = h_{t-1}*exp(dt_t a) + dt_t B_t x_t."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    bh = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    ch = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    da = jnp.exp(dt.astype(jnp.float32) * a[None, None, :])

    def step(state, inputs):
        xt, dtt, dat, bt, ct = inputs
        state = state * dat[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", bt, xt * dtt[..., None])
        y = jnp.einsum("bhn,bhnp->bhp", ct, state)
        return state, y

    init = jnp.zeros((bsz, h, n, p), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(da, 1, 0), jnp.moveaxis(bh, 1, 0),
          jnp.moveaxis(ch, 1, 0))
    final, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1), final


def _inputs(key, bsz=2, s=32, h=4, p=8, g=2, n=16):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bsz, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
    a = -jnp.exp(jax.random.uniform(ks[2], (h,), minval=0.0, maxval=1.0))
    b = jax.random.normal(ks[3], (bsz, s, g, n)) * 0.5
    c = jax.random.normal(ks[4], (bsz, s, g, n)) * 0.5
    return x, dt, a, b, c


def test_ssd_chunked_matches_sequential():
    x, dt, a, b, c = _inputs(jax.random.key(0))
    y_ref, s_ref = ssd_sequential(x, dt, a, b, c)
    for chunk in (8, 16, 32):
        y, s_f = ssm.ssd_chunked(x, dt, a, b, c, chunk)
        np.testing.assert_allclose(y, y_ref, atol=2e-4)
        np.testing.assert_allclose(s_f, s_ref, atol=2e-4)


if st is not None:
    @settings(max_examples=10, deadline=None)
    @given(h=st.sampled_from([2, 4]), g=st.sampled_from([1, 2]),
           n=st.sampled_from([4, 16]), chunk=st.sampled_from([4, 8, 16]),
           seed=st.integers(0, 50))
    def test_ssd_property_sweep(h, g, n, chunk, seed):
        if h % g:
            return
        x, dt, a, b, c = _inputs(jax.random.key(seed), h=h, g=g, n=n, s=16)
        y_ref, _ = ssd_sequential(x, dt, a, b, c)
        y, _ = ssm.ssd_chunked(x, dt, a, b, c, chunk if chunk <= 16 else 16)
        np.testing.assert_allclose(y, y_ref, atol=3e-4)
else:
    def test_ssd_property_sweep():
        pytest.importorskip("hypothesis")


def test_decode_step_matches_chunked():
    x, dt, a, b, c = _inputs(jax.random.key(2), s=8)
    y_ref, _ = ssm.ssd_chunked(x, dt, a, b, c, 8)
    state = jnp.zeros((2, 4, 16, 8), jnp.float32)
    ys = []
    for t in range(8):
        y, state = ssm.ssd_decode_step(state, x[:, t], dt[:, t], a,
                                       b[:, t], c[:, t])
        ys.append(y)
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_dec, y_ref, atol=2e-4)


def test_initial_state_threading():
    """prefill(first half) + prefill(second half) == prefill(full)."""
    x, dt, a, b, c = _inputs(jax.random.key(3), s=32)
    y_full, s_full = ssm.ssd_chunked(x, dt, a, b, c, 8)
    y1, s1 = ssm.ssd_chunked(x[:, :16], dt[:, :16], a, b[:, :16], c[:, :16], 8)
    y2, s2 = ssm.ssd_chunked(x[:, 16:], dt[:, 16:], a, b[:, 16:], c[:, 16:],
                             8, initial_state=s1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, atol=2e-4)
    np.testing.assert_allclose(s2, s_full, atol=2e-4)
