"""End-to-end behaviour + paper-claims regression (one assert per Takeaway)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import analytical, distmodel
from repro.core.roofline import MI100, MI100_FP32, V5E


BERT = get_config("bert-large")


def _shares(b, n, dev, db):
    times = analytical.phase_times(BERT, b, n, dev=dev, dtype_bytes=db)
    tot = sum(times.values())
    gemm = sum(v for k, v in times.items()
               if k in ("attn_linear", "attn_bgemm", "fc", "head")) / tot
    return times, tot, gemm


def test_takeaway_1_transformer_dominates():
    times, tot, _ = _shares(32, 128, MI100_FP32, 4)
    transformer = sum(v for k, v in times.items()
                      if k not in ("lamb", "loss", "head"))
    assert transformer / tot > 0.7


def test_takeaway_2_lamb_second_and_grows_with_small_batch():
    t32, tot32, _ = _shares(32, 128, MI100_FP32, 4)
    t4, tot4, _ = _shares(4, 128, MI100_FP32, 4)
    assert t4["lamb"] / tot4 > t32["lamb"] / tot32
    assert t4["lamb"] / tot4 > 0.1


def test_takeaway_3_lamb_share_rises_with_mixed_precision():
    t32, tot32, _ = _shares(32, 128, MI100_FP32, 4)
    tmp, totmp, _ = _shares(32, 128, MI100, 2)
    assert tmp["lamb"] / totmp > t32["lamb"] / tot32


def test_takeaway_4_fc_and_linear_dominate_transformer():
    times, tot, gemm = _shares(32, 128, MI100_FP32, 4)
    assert times["fc"] > times["attn_linear"] > times["attn_bgemm"]
    assert gemm > 0.5


def test_takeaway_5_nongemm_share_rises_with_reduced_precision():
    _, _, g32 = _shares(32, 128, MI100_FP32, 4)
    _, _, gmp = _shares(32, 128, MI100, 2)
    assert (1 - gmp) > (1 - g32)


def test_takeaway_6_no_matrix_vector_at_b1():
    gs = analytical.transformer_gemms(BERT, 1, 128)
    for g in gs:
        assert g.m > 1 and g.n > 1, (g.name, g.m, g.n)


def test_takeaway_7_attention_bgemms_memory_bound():
    gs = {g.name: g for g in analytical.transformer_gemms(BERT, 32, 128)}
    # ops/byte below the MI100 fp32 machine balance => memory-bound
    balance = MI100_FP32.peak_flops / MI100_FP32.hbm_bw
    assert gs["attn_score"].intensity(4) < balance
    assert gs["fc1"].intensity(4) > balance


def test_takeaway_8_lamb_reads_4x_model():
    ops = analytical.nongemm_ops(BERT, 32, 128)
    stage1 = next(e for e in ops if e.name == "lamb_stage1")
    model_bytes = BERT.param_count() * 4
    reads = 4 * model_bytes          # w, g, m, v
    assert stage1.total_bytes >= reads
    assert stage1.intensity < 1.0    # deeply memory-bound


def test_takeaway_9_nongemm_is_30_40_pct_fp32():
    _, _, gemm = _shares(32, 128, MI100_FP32, 4)
    assert 0.1 < 1 - gemm < 0.45


def test_takeaway_11_token_count_drives_lamb_share():
    t_small, tot_small, _ = _shares(4, 128, MI100_FP32, 4)
    t_big, tot_big, _ = _shares(32, 512, MI100_FP32, 4)
    assert t_small["lamb"] / tot_small > 3 * (t_big["lamb"] / tot_big)


def test_takeaway_13_gemm_share_rises_with_width():
    def gemm_share(width):
        arch = dataclasses.replace(BERT, d_model=width, d_ff=4 * width,
                                   head_dim=width // 16)
        times = analytical.phase_times(arch, 32, 128, dev=MI100_FP32,
                                       dtype_bytes=4)
        tot = sum(times.values())
        return sum(v for k, v in times.items()
                   if k in ("attn_linear", "attn_bgemm", "fc", "head")) / tot
    assert gemm_share(4096) > gemm_share(1024) > gemm_share(768)


def test_takeaway_14_dp_overlap_hides_comm():
    profs = distmodel.figure12(BERT)
    d1 = profs["D1 (DP64 B=16, overlap)"]
    d2 = profs["D2 (DP64 B=16, no overlap)"]
    s1 = profs["S1 (single, B=16)"]
    assert d1.total < 1.1 * s1.total          # overlap ~ single-device profile
    assert d2.comm_time > 5 * d1.comm_time    # exposed without overlap


def test_takeaway_15_mp_lamb_shrinks_comm_grows():
    profs = distmodel.figure12(BERT)
    m1, m2 = profs["M1 (MP2, B=16)"], profs["M2 (MP8, B=64)"]
    assert m2.breakdown()["lamb"] < m1.breakdown()["lamb"]
    assert m2.comm_time > m1.comm_time
    assert m2.comm_time / m2.total > 0.3      # paper: ~42% at MP8


def test_training_learns_end_to_end():
    from repro.launch.train import main
    out = main(["--arch", "bert-large", "--smoke", "--batch", "8",
                "--seq", "32", "--steps", "30"])
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]
    assert all(jnp.isfinite(jnp.asarray(losses)))


def test_train_loop_keeps_one_step_in_flight():
    """The current step's metrics must never be synced inside its own step
    (that serializes async dispatch — the device drains before the next step
    is enqueued); the loop syncs with pipeline depth 1, so step N's metrics
    materialize only after step N+1 has been dispatched — yet the returned
    history still carries plain-float metrics for every step."""
    from repro.data import DataConfig, SyntheticPipeline
    from repro.train.loop import LoopConfig, train_loop

    events = []

    class DeviceMetric:
        """Stands in for a device array; records when it's materialized."""
        def __init__(self, step):
            self.step = step

        def __array__(self, dtype=None):
            events.append(("sync", self.step))
            return jnp.asarray(float(self.step) + 0.5).__array__(dtype)

    def step_fn(state, batch):
        events.append(("dispatch", state))
        return state + 1, {"loss": DeviceMetric(state)}

    data = SyntheticPipeline(DataConfig(vocab_size=50, seq_len=8,
                                        global_batch=2))
    # huge straggler_factor: instant fake steps have wild dt ratios, and a
    # straggler is a sanctioned eager-flush boundary that would mask the lag
    cfg = LoopConfig(max_steps=10, log_every=4, ckpt_every=10**9,
                     straggler_factor=1e9)
    out = train_loop(step_fn, 0, data, cfg, log=lambda s: None)
    # every entry materialized by the end, values intact
    assert [h["loss"] for h in out["history"]] == \
        [s + 0.5 for s in range(10)]
    assert all(isinstance(h["loss"], float) for h in out["history"])
    # depth-1 pipeline: a step's metrics are synced only after the next step
    # was dispatched (log boundaries report the previous, completed step;
    # only the very first log line syncs its own step)
    order = {e: i for i, e in enumerate(events)}
    for s in range(1, 9):
        assert order[("sync", s)] > order[("dispatch", s + 1)], \
            f"step {s} synced inside its own step"
    assert order[("sync", 9)] > order[("dispatch", 9)]   # end-of-loop flush
