"""jaxlint rule fixtures: every rule has at least one true-positive snippet
(the defect is reported) and one true-negative (the correct idiom is not),
plus the allow-annotation contract and the acceptance gate that the repo's
own tree lints clean.

The linter is stdlib-only AST analysis, so these tests never import jax —
the fixtures are strings, never executed.
"""
from pathlib import Path

import pytest

from repro.analysis.lint import RULES, lint_source

ROOT = Path(__file__).resolve().parents[1]


def rules_of(src: str):
    return [f.rule for f in lint_source(src)]


def assert_flags(src: str, rule: str):
    found = rules_of(src)
    assert rule in found, f"expected {rule}, got {found}\n--\n{src}"


def assert_clean(src: str, rule: str):
    found = rules_of(src)
    assert rule not in found, f"false positive {rule}: " \
        f"{[str(f) for f in lint_source(src)]}\n--\n{src}"


# ------------------------------------------------------------ jit-host-sync --

def test_jit_host_sync_item_flagged():
    assert_flags("""
import jax

@jax.jit
def f(x):
    return x.item()
""", "jit-host-sync")


def test_jit_host_sync_float_on_traced_flagged():
    assert_flags("""
import jax

@jax.jit
def f(x):
    return float(x)
""", "jit-host-sync")


def test_jit_host_sync_numpy_on_traced_flagged():
    assert_flags("""
import jax
import numpy as np

@jax.jit
def f(x):
    return np.asarray(x) + 1
""", "jit-host-sync")


def test_jit_host_sync_applies_to_jit_call_targets():
    # jit applied by name, not decorator — same trace context
    assert_flags("""
import jax

def step(x):
    return x.tolist()

g = jax.jit(step)
""", "jit-host-sync")


def test_jit_host_sync_shape_access_clean():
    assert_clean("""
import jax

@jax.jit
def f(x):
    b = x.shape[0]
    return x.reshape(b, -1)
""", "jit-host-sync")


def test_jit_host_sync_numpy_on_host_value_clean():
    assert_clean("""
import jax
import numpy as np

@jax.jit
def f(x, *, n):
    mask = np.zeros((n,), np.int32)      # n is keyword-only -> static
    return x * mask
""", "jit-host-sync")


# ------------------------------------------------------------ hot-host-sync --

def test_hot_host_sync_per_step_pull_flagged():
    assert_flags("""
import jax
import numpy as np

def serve(xs):
    step = jax.jit(lambda x: x + 1)
    out = []
    for x in xs:
        y = step(x)
        out.append(float(y))
    return out
""", "hot-host-sync")


def test_hot_host_sync_block_until_ready_in_loop_flagged():
    assert_flags("""
import jax

def bench(xs):
    step = jax.jit(lambda x: x + 1)
    for x in xs:
        step(x).block_until_ready()
""", "hot-host-sync")


def test_hot_host_sync_engine_fn_idiom_flagged():
    # `self._decode_fn(...)(...)` — a compiled step fetched then called
    assert_flags("""
import numpy as np

class Engine:
    def run(self, steps):
        for _ in range(steps):
            toks, self.pools = self._decode_fn(True, False)(self.pools)
            out = np.asarray(toks)
""", "hot-host-sync")


def test_hot_host_sync_post_loop_sync_clean():
    assert_clean("""
import jax

def serve(xs):
    step = jax.jit(lambda x: x + 1)
    ys = []
    for x in xs:
        ys.append(step(x))
    jax.block_until_ready(ys)
    return ys
""", "hot-host-sync")


def test_hot_host_sync_host_array_indexing_clean():
    # int() on a numpy-derived name is host work, not a device sync
    assert_clean("""
import jax
import numpy as np

def serve(xs):
    step = jax.jit(lambda x: x + 1)
    for x in xs:
        y = step(x)
        y_np = np.asarray(y)  # jaxlint: allow[hot-host-sync] fixture
        first = int(y_np[0])
""", "hot-host-sync")


# ------------------------------------------------------------ tracer-branch --

def test_tracer_branch_if_flagged():
    assert_flags("""
import jax

@jax.jit
def f(x):
    if x > 0:
        return x
    return -x
""", "tracer-branch")


def test_tracer_branch_for_over_traced_flagged():
    assert_flags("""
import jax

@jax.jit
def f(x, n):
    acc = x
    for _ in range(n):
        acc = acc + 1
    return acc
""", "tracer-branch")


def test_tracer_branch_keyword_only_flag_clean():
    # the repo's jit-variant idiom: keyword-only params are static flags
    assert_clean("""
import jax

@jax.jit
def f(x, *, sampled):
    if sampled:
        return x * 2
    return x
""", "tracer-branch")


def test_tracer_branch_shape_dispatch_clean():
    assert_clean("""
import jax

@jax.jit
def f(x):
    if x.ndim == 2:
        return x
    return x[None]
""", "tracer-branch")


# ----------------------------------------------------------- prng-key-reuse --

def test_key_reuse_double_consumption_flagged():
    assert_flags("""
import jax

def f(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))
    return a + b
""", "prng-key-reuse")


def test_key_reuse_in_loop_without_rebind_flagged():
    assert_flags("""
import jax

def f(key):
    out = []
    for _ in range(4):
        out.append(jax.random.normal(key, ()))
    return out
""", "prng-key-reuse")


def test_key_reuse_split_clean():
    assert_clean("""
import jax

def f(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (4,))
    b = jax.random.uniform(k2, (4,))
    return a + b
""", "prng-key-reuse")


def test_key_reuse_loop_rebind_clean():
    assert_clean("""
import jax

def f(key):
    out = []
    for _ in range(4):
        key, sub = jax.random.split(key)
        out.append(jax.random.normal(sub, ()))
    return out
""", "prng-key-reuse")


# ------------------------------------------------------- nonhashable-static --

def test_nonhashable_static_list_flagged():
    assert_flags("""
import jax

def f(x, sizes):
    return x

g = jax.jit(f, static_argnames=("sizes",))
y = g(1, sizes=[1, 2, 3])
""", "nonhashable-static")


def test_nonhashable_static_tuple_clean():
    assert_clean("""
import jax

def f(x, sizes):
    return x

g = jax.jit(f, static_argnames=("sizes",))
y = g(1, sizes=(1, 2, 3))
""", "nonhashable-static")


# --------------------------------------------------------------- fstring-sync --

def test_fstring_on_traced_flagged():
    assert_flags("""
import jax

@jax.jit
def f(x):
    print(f"x is {x}")
    return x
""", "fstring-sync")


def test_fstring_on_shape_clean():
    assert_clean("""
import jax

@jax.jit
def f(x):
    print(f"shape {x.shape}")
    return x
""", "fstring-sync")


def test_fstring_on_device_value_in_hot_loop_flagged():
    assert_flags("""
import jax

def serve(xs, log):
    step = jax.jit(lambda x: x + 1)
    for x in xs:
        y = step(x)
        log(f"step result {y}")
""", "fstring-sync")


# ------------------------------------------------------- pallas-grid-floordiv --

def test_pallas_grid_floordiv_flagged():
    assert_flags("""
from jax.experimental import pallas as pl
import jax

def kern(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2

def call(x):
    return pl.pallas_call(kern, grid=(x.shape[0] // 8,),
                          out_shape=x)(x)
""", "pallas-grid-floordiv")


def test_pallas_grid_cdiv_clean():
    assert_clean("""
from jax.experimental import pallas as pl
import jax.numpy as jnp

def kern(x_ref, o_ref):
    pl.when(pl.program_id(0) < 4)(lambda: None)
    o_ref[...] = x_ref[...] * 2

def call(x):
    return pl.pallas_call(kern, grid=(pl.cdiv(x.shape[0], 8),),
                          out_shape=x)(x)
""", "pallas-grid-floordiv")


def test_pallas_grid_negative_floordiv_ceil_idiom_clean():
    assert_clean("""
from jax.experimental import pallas as pl

def kern(x_ref, o_ref):
    o_ref[...] = x_ref[...]

def call(x, n):
    return pl.pallas_call(kern, grid=(-(-n // 8),), out_shape=x)(x)
""", "pallas-grid-floordiv")


# -------------------------------------------------------- pallas-accum-dtype --

def test_pallas_accum_dtype_bare_dot_flagged():
    assert_flags("""
from jax.experimental import pallas as pl
import jax.numpy as jnp

def kern(q_ref, k_ref, o_ref):
    o_ref[...] = jnp.dot(q_ref[...], k_ref[...])

def call(q, k, out):
    return pl.pallas_call(kern, grid=(4,), out_shape=out)(q, k)
""", "pallas-accum-dtype")


def test_pallas_accum_dtype_preferred_element_type_clean():
    assert_clean("""
from jax.experimental import pallas as pl
import jax.numpy as jnp

def kern(q_ref, k_ref, o_ref):
    o_ref[...] = jnp.dot(q_ref[...], k_ref[...],
                         preferred_element_type=jnp.float32)

def call(q, k, out):
    return pl.pallas_call(kern, grid=(4,), out_shape=out)(q, k)
""", "pallas-accum-dtype")


def test_pallas_accum_dtype_fp32_cast_operand_clean():
    # the decode-attention kernels' idiom: operands astype'd to fp32 first
    assert_clean("""
from jax.experimental import pallas as pl
import jax.numpy as jnp

def kern(q_ref, k_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.dot(q, k_ref[...])

def call(q, k, out):
    return pl.pallas_call(kern, grid=(4,), out_shape=out)(q, k)
""", "pallas-accum-dtype")


# ------------------------------------------------------- pallas-partial-mask --

def test_pallas_partial_mask_cdiv_unmasked_flagged():
    assert_flags("""
from jax.experimental import pallas as pl

def kern(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2

def call(x, n):
    return pl.pallas_call(kern, grid=(pl.cdiv(n, 8),), out_shape=x)(x)
""", "pallas-partial-mask")


def test_pallas_partial_mask_when_clean():
    assert_clean("""
from jax.experimental import pallas as pl

def kern(x_ref, o_ref):
    @pl.when(pl.program_id(0) < 3)
    def _():
        o_ref[...] = x_ref[...] * 2

def call(x, n):
    return pl.pallas_call(kern, grid=(pl.cdiv(n, 8),), out_shape=x)(x)
""", "pallas-partial-mask")


def test_pallas_exact_grid_needs_no_mask():
    assert_clean("""
from jax.experimental import pallas as pl

def kern(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2

def call(x):
    r = x.shape[0]
    assert r % 8 == 0
    # jaxlint: allow[pallas-grid-floordiv] divisibility asserted above
    return pl.pallas_call(kern, grid=(r // 8,), out_shape=x)(x)
""", "pallas-partial-mask")


# ---------------------------------------------------------------- allow[] ----

def test_allow_suppresses_on_same_line():
    assert_clean("""
import jax

@jax.jit
def f(x):
    return x.item()  # jaxlint: allow[jit-host-sync] fixture justification
""", "jit-host-sync")


def test_allow_suppresses_from_comment_block_above():
    assert_clean("""
import jax

@jax.jit
def f(x):
    # jaxlint: allow[jit-host-sync] the one designed sync; the host
    # scheduler needs this value before the next step
    return x.item()
""", "jit-host-sync")


def test_allow_does_not_leak_to_other_lines():
    src = """
import jax

@jax.jit
def f(x):
    y = x.item()  # jaxlint: allow[jit-host-sync] fixture
    return float(x)
"""
    assert rules_of(src).count("jit-host-sync") == 1


def test_allow_unknown_rule_reported():
    assert_flags("""
x = 1  # jaxlint: allow[definitely-not-a-rule] why not
""", "allow-unknown-rule")


def test_allow_missing_reason_reported():
    assert_flags("""
import jax

@jax.jit
def f(x):
    return x.item()  # jaxlint: allow[jit-host-sync]
""", "allow-missing-reason")


def test_rule_catalog_is_documented():
    # every reportable rule id has a catalog entry (drives --list-rules)
    for f in lint_source("import jax\n@jax.jit\ndef f(x):\n    return x.item()\n"):
        assert f.rule in RULES


# ------------------------------------------------------------ the real tree --

@pytest.mark.parametrize("tree", ["src", "benchmarks", "tools"])
def test_repo_lints_clean(tree):
    """The acceptance gate: the repo's own code has no unannotated
    violations (CI runs the same check as a dedicated lint job)."""
    from repro.analysis.lint import lint_paths
    findings = lint_paths([str(ROOT / tree)])
    assert not findings, "\n".join(str(f) for f in findings)
