"""Distribution: multi-device (8 host CPUs, subprocess) equivalence tests —
TP+FSDP sharded train step == single-device step; decode sharded == unsharded;
plus in-process spec/rule unit tests."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as sh

ROOT = Path(__file__).resolve().parents[1]


def _run_subprocess(body: str):
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
        "os.environ['JAX_PLATFORMS'] = 'cpu'\n" + body)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=ROOT, timeout=500,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    return r.stdout


def test_param_rules_cover_all_archs():
    from repro.configs import REGISTRY, smoke_config
    from repro.models import build_model
    for name in REGISTRY:
        arch = smoke_config(name)
        model = build_model(arch)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.key(0)))
        specs = sh.param_pspecs(params)      # raises if any leaf unmatched
        assert len(jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
                   ) == len(jax.tree.leaves(params))


def test_sanitize_spec_drops_indivisible_axes():
    sizes = {"data": 16, "model": 16}
    assert sh._sanitize(P("data", None), (1, 16), sizes) == P(None, None)
    assert sh._sanitize(P("data",), (7,), sizes) == P(None)
    assert sh._sanitize(P("data", "model"), (32, 32), sizes) == \
        P("data", "model")
    # partial tuple keep: 16 divides, 256 doesn't
    assert sh._sanitize(P(("data", "model"),), (16,), sizes) == P("data")


def test_tp_fsdp_train_step_matches_single_device():
    """2x4 (data x model) sharded train step == unsharded, bit-for-bit-ish."""
    out = _run_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import RunConfig, ShapeConfig, smoke_config
from repro.train.steps import build_train_step
from repro.parallel import sharding as sh
from repro.launch.mesh import make_mesh

arch = smoke_config("internlm2-1.8b")
shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train",
                    microbatches=2)
run = RunConfig(arch=arch, shape=shape, zero1=True, master_weights=True)
bundle = build_train_step(run)
tokens = jax.random.randint(jax.random.key(1), (4, 32), 5, arch.vocab_size)
batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1),
         "loss_mask": jnp.ones((4, 32), jnp.bfloat16)}

# single device
state0 = bundle.init(0)
s1, m1 = jax.jit(bundle.fn)(state0, batch)

# sharded on (2, 4)
mesh = make_mesh((2, 4), ("data", "model"))
rules = sh.make_rules()
with sh.activate(mesh, rules):
    state = bundle.init(0)
    specs = sh.sanitize_tree(bundle.state_specs(state), state)
    st_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                         is_leaf=lambda x: isinstance(x, P))
    b_specs = sh.sanitize_tree(sh.batch_pspecs(batch), batch)
    b_sh = {k: NamedSharding(mesh, s) for k, s in b_specs.items()}
    state = jax.device_put(state, st_sh)
    batch_d = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
    s2, m2 = jax.jit(bundle.fn, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None))(state, batch_d)

print("loss1", float(m1["loss"]), "loss2", float(m2["loss"]))
assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2
for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
    d = float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
    assert d < 5e-2, d
print("TP_FSDP_EQUIV_OK")
""")
    assert "TP_FSDP_EQUIV_OK" in out


def test_decode_sharded_matches_unsharded():
    out = _run_subprocess(r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import smoke_config
from repro.models import build_model
from repro.parallel import sharding as sh
from repro.launch.mesh import make_mesh

arch = smoke_config("llama3.2-3b")
model = build_model(arch)
params = jax.tree.map(lambda p: p.astype(jnp.bfloat16),
                      model.init(jax.random.key(0)))
caches = model.init_caches(None, 4, 64)
batch = {"tokens": jnp.full((4, 1), 42), "positions": jnp.zeros((4,), jnp.int32)}
l1, _ = jax.jit(model.decode_step)(params, caches, batch)

mesh = make_mesh((2, 4), ("data", "model"))
with sh.activate(mesh, sh.make_rules()):
    pspecs = sh.sanitize_tree(sh.param_pspecs(params), params)
    cspecs = sh.sanitize_tree(sh.cache_pspecs(caches), caches)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
    c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                        is_leaf=lambda x: isinstance(x, P))
    l2, _ = jax.jit(model.decode_step,
                    in_shardings=(p_sh, c_sh, None))(
        jax.device_put(params, p_sh), jax.device_put(caches, c_sh), batch)
d = float(jnp.max(jnp.abs(l1 - l2)))
assert d < 0.1, d
print("DECODE_SHARD_OK", d)
""")
    assert "DECODE_SHARD_OK" in out
