"""Distribution: multi-device (8 host CPUs, subprocess) equivalence tests —
TP+FSDP sharded train step == single-device step; decode sharded == unsharded;
plus in-process spec/rule unit tests."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as sh

ROOT = Path(__file__).resolve().parents[1]


def _run_subprocess(body: str):
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
        "os.environ['JAX_PLATFORMS'] = 'cpu'\n" + body)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=ROOT, timeout=500,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    return r.stdout


def test_param_rules_cover_all_archs():
    from repro.configs import REGISTRY, smoke_config
    from repro.models import build_model
    for name in REGISTRY:
        arch = smoke_config(name)
        model = build_model(arch)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.key(0)))
        specs = sh.param_pspecs(params)      # raises if any leaf unmatched
        assert len(jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
                   ) == len(jax.tree.leaves(params))


def test_sanitize_spec_drops_indivisible_axes():
    sizes = {"data": 16, "model": 16}
    assert sh._sanitize(P("data", None), (1, 16), sizes) == P(None, None)
    assert sh._sanitize(P("data",), (7,), sizes) == P(None)
    assert sh._sanitize(P("data", "model"), (32, 32), sizes) == \
        P("data", "model")
    # partial tuple keep: 16 divides, 256 doesn't
    assert sh._sanitize(P(("data", "model"),), (16,), sizes) == P("data")


def test_sanitize_spec_pins_silent_drop_semantics():
    """sanitize_spec's contract is *silent* axis dropping, never an error —
    the sharded decode/train paths (and the TP serving specs built next to
    them) lean on that for shapes a mesh axis doesn't divide. Pin the exact
    semantics: per-dim independence, rank padding, tuple-prefix keeps in
    declaration order."""
    sizes = {"data": 8, "model": 4}
    # spec shorter than the shape: missing dims are padded replicated
    assert sh._sanitize(P("model"), (8, 12), sizes) == P("model", None)
    # each dim is sanitized independently — one bad dim doesn't strip others
    assert sh._sanitize(P("data", "model"), (7, 12), sizes) == P(None, "model")
    # tuples keep the longest dividing prefix IN ORDER: over dim 8,
    # ("data","model") keeps data (8|8) then drops model (8*4 does not
    # divide 8), while ("model","data") keeps model (4|8) then drops data
    assert sh._sanitize(P(("data", "model"),), (8,), sizes) == P("data")
    assert sh._sanitize(P(("model", "data"),), (8,), sizes) == P("model")
    # dropping is total when nothing divides
    assert sh._sanitize(P(None, "model"), (3, 5), sizes) == P(None, None)
    # size-1 mesh axes always survive (1 divides everything)
    assert sh._sanitize(P("model",), (5,), {"model": 1}) == P("model")
    # and a no-mesh context is the identity (sanitize_spec's public guard)
    assert sh.sanitize_spec(P("data", "model"), (3, 5)) == P("data", "model")


def test_param_pspecs_sanitize_on_undividable_shapes():
    """param_pspecs + sanitize on an arch whose d_ff does not divide the
    model axis: the tensor dim's sharding is dropped silently while every
    dividing dim keeps its axis — the behavior the sharded decode path and
    the serving TP engine assume when they feed jit mesh-divisible inputs."""
    import dataclasses
    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.launch.mesh import make_host_mesh

    arch = dataclasses.replace(smoke_config("llama3.2-3b"), d_ff=300)
    model = build_model(arch)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    # a 1x1 host mesh binds the axis *names*; divisibility is checked
    # against the production axis sizes below
    with sh.activate(make_host_mesh(1, 1), sh.make_rules()):
        specs = sh.param_pspecs(params)
    sizes = {"data": 16, "model": 16}
    by_name = {}
    for kp, spec in jax.tree_util.tree_leaves_with_path(
            specs, is_leaf=lambda s: isinstance(s, P)):
        by_name.setdefault(kp[-1].key, []).append(spec)
    leaves = {kp[-1].key: leaf for kp, leaf in
              jax.tree_util.tree_leaves_with_path(params)}

    w1 = sh._sanitize(by_name["w1"][0], leaves["w1"].shape, sizes)
    # d_model=128 divides 16 -> fsdp kept; d_ff=300 doesn't -> tensor dropped
    assert w1[-2] == "data" and w1[-1] is None
    w2 = sh._sanitize(by_name["w2"][0], leaves["w2"].shape, sizes)
    assert w2[-2] is None and w2[-1] == "data"
    # attention dims (q_dim=128) still divide: wq keeps both axes
    wq = sh._sanitize(by_name["wq"][0], leaves["wq"].shape, sizes) \
        if "wq" in by_name else None
    wqkv = sh._sanitize(by_name["wqkv"][0], leaves["wqkv"].shape, sizes) \
        if "wqkv" in by_name else None
    kept = wq if wq is not None else wqkv
    assert kept[-2] == "data" and kept[-1] == "model"


def test_tp_fsdp_train_step_matches_single_device():
    """2x4 (data x model) sharded train step == unsharded, bit-for-bit-ish."""
    out = _run_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import RunConfig, ShapeConfig, smoke_config
from repro.train.steps import build_train_step
from repro.parallel import sharding as sh
from repro.launch.mesh import make_mesh

arch = smoke_config("internlm2-1.8b")
shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train",
                    microbatches=2)
run = RunConfig(arch=arch, shape=shape, zero1=True, master_weights=True)
bundle = build_train_step(run)
tokens = jax.random.randint(jax.random.key(1), (4, 32), 5, arch.vocab_size)
batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1),
         "loss_mask": jnp.ones((4, 32), jnp.bfloat16)}

# single device
state0 = bundle.init(0)
s1, m1 = jax.jit(bundle.fn)(state0, batch)

# sharded on (2, 4)
mesh = make_mesh((2, 4), ("data", "model"))
rules = sh.make_rules()
with sh.activate(mesh, rules):
    state = bundle.init(0)
    specs = sh.sanitize_tree(bundle.state_specs(state), state)
    st_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                         is_leaf=lambda x: isinstance(x, P))
    b_specs = sh.sanitize_tree(sh.batch_pspecs(batch), batch)
    b_sh = {k: NamedSharding(mesh, s) for k, s in b_specs.items()}
    state = jax.device_put(state, st_sh)
    batch_d = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
    s2, m2 = jax.jit(bundle.fn, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None))(state, batch_d)

print("loss1", float(m1["loss"]), "loss2", float(m2["loss"]))
assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2
for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
    d = float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
    assert d < 5e-2, d
print("TP_FSDP_EQUIV_OK")
""")
    assert "TP_FSDP_EQUIV_OK" in out


def test_decode_sharded_matches_unsharded():
    out = _run_subprocess(r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import smoke_config
from repro.models import build_model
from repro.parallel import sharding as sh
from repro.launch.mesh import make_mesh

arch = smoke_config("llama3.2-3b")
model = build_model(arch)
params = jax.tree.map(lambda p: p.astype(jnp.bfloat16),
                      model.init(jax.random.key(0)))
caches = model.init_caches(None, 4, 64)
batch = {"tokens": jnp.full((4, 1), 42), "positions": jnp.zeros((4,), jnp.int32)}
l1, _ = jax.jit(model.decode_step)(params, caches, batch)

mesh = make_mesh((2, 4), ("data", "model"))
with sh.activate(mesh, sh.make_rules()):
    pspecs = sh.sanitize_tree(sh.param_pspecs(params), params)
    cspecs = sh.sanitize_tree(sh.cache_pspecs(caches), caches)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
    c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                        is_leaf=lambda x: isinstance(x, P))
    l2, _ = jax.jit(model.decode_step,
                    in_shardings=(p_sh, c_sh, None))(
        jax.device_put(params, p_sh), jax.device_put(caches, c_sh), batch)
d = float(jnp.max(jnp.abs(l1 - l2)))
assert d < 0.1, d
print("DECODE_SHARD_OK", d)
""")
    assert "DECODE_SHARD_OK" in out
