"""LAMB: Fig-3 algebra, fused-kernel == reference, ZeRO layout == dense layout,
master-weight path, grad accumulation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import grad as grad_lib
from repro.optim import lamb


def _params():
    return {"blocks": {"w1": jax.random.normal(jax.random.key(0), (3, 8, 32)),
                       "b1": jax.random.normal(jax.random.key(1), (3, 32))},
            "embed": {"embedding": jax.random.normal(jax.random.key(2),
                                                     (64, 8))}}


def _grads(params):
    return jax.tree.map(lambda p: 0.01 * p + 0.001, params)


def test_fig3_algebra_single_tensor():
    """One step of LAMB on a single tensor must match a literal Fig-3 transcription."""
    cfg = lamb.LambConfig(zero1=False, master_weights=False, weight_decay=0.01,
                          learning_rate=0.1)
    w = jax.random.normal(jax.random.key(5), (16,))
    g = jax.random.normal(jax.random.key(6), (16,))
    params = {"w": w}
    state = lamb.init(cfg, params)
    new_params, new_state = lamb.update(cfg, {"w": g}, state, params)

    # literal Fig 3
    gprime = jnp.linalg.norm(g)
    ghat = g / gprime
    m = (1 - cfg.beta1) * ghat
    v = (1 - cfg.beta2) * ghat ** 2
    mhat = m / (1 - cfg.beta1)
    vhat = v / (1 - cfg.beta2)
    u = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * w
    r = jnp.linalg.norm(w) / jnp.linalg.norm(u)
    w_expected = w - cfg.learning_rate * r * u
    np.testing.assert_allclose(new_params["w"], w_expected, rtol=1e-5)


def test_zero_layout_matches_dense_layout():
    params = _params()
    grads = _grads(params)
    cfg_d = lamb.LambConfig(zero1=False, master_weights=False)
    cfg_z = lamb.LambConfig(zero1=True, master_weights=False, pad_multiple=16)
    sd = lamb.init(cfg_d, params)
    sz = lamb.init(cfg_z, params)
    pd, _ = lamb.update(cfg_d, grads, sd, params)
    pz, _ = lamb.update(cfg_z, grads, sz, params)
    for a, b in zip(jax.tree.leaves(pd), jax.tree.leaves(pz)):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-6)


def test_master_weights_bf16_params():
    params32 = _params()
    params16 = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params32)
    grads = jax.tree.map(lambda p: p.astype(jnp.bfloat16), _grads(params32))
    cfg = lamb.LambConfig(zero1=True, master_weights=True, pad_multiple=16)
    state = lamb.init(cfg, params32)     # master derives from fp32 init
    new_p, new_s = lamb.update(cfg, grads, state, params16)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(new_p))
    # master must advance in fp32
    assert all(l.dtype == jnp.float32
               for l in jax.tree.leaves(new_s["master"]))


def test_fused_kernel_path_matches_reference():
    cfg_ref = lamb.LambConfig(zero1=True, master_weights=False,
                              pad_multiple=16)
    params = {"w": jax.random.normal(jax.random.key(1), (4, 64))}
    grads = {"w": jax.random.normal(jax.random.key(2), (4, 64))}
    s0 = lamb.init(cfg_ref, params)
    p_ref, s_ref = lamb.update(cfg_ref, grads, s0, params)

    from repro.kernels.fused_lamb import ops as fused_ops
    from repro.kernels.fused_lamb import ref as fused_ref
    w = params["w"].astype(jnp.float32)
    kw = dict(ginv=0.7, c1=1.2, c2=1.1, beta1=0.9, beta2=0.999, eps=1e-6,
              weight_decay=0.01, lr=1e-3)
    m0 = s0["m"]["w"].reshape(w.shape)
    v0 = s0["v"]["w"].reshape(w.shape)
    a = fused_ops.lamb_stage12(w, grads["w"].astype(jnp.float32),
                               m0, v0, interpret=True, **kw)
    b = fused_ref.lamb_stage12(w, grads["w"].astype(jnp.float32),
                               m0, v0, **kw)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, atol=1e-5)


def test_grad_accumulation_equivalence():
    """mean of microbatch grads == full-batch grads (linear loss in batch)."""
    w = jnp.ones((8,))

    def loss(p, batch):
        x = batch["x"]
        return jnp.mean((x @ p) ** 2), {"loss": jnp.mean((x @ p) ** 2)}

    x = jax.random.normal(jax.random.key(0), (8, 8))
    g1, _ = grad_lib.accumulate_microbatches(loss, w, {"x": x}, 1)
    g4, _ = grad_lib.accumulate_microbatches(loss, w, {"x": x}, 4)
    np.testing.assert_allclose(g1, g4, rtol=1e-5)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0), "b": jnp.full((3,), -10.0)}
    clipped, norm = grad_lib.clip_by_global_norm(grads, 1.0)
    assert float(norm) > 1.0
    assert abs(float(grad_lib.global_norm(clipped)) - 1.0) < 1e-5
