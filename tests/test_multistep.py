"""Multi-step compiled decode loop: N iterations per host dispatch.

The acceptance surface of the multi-step loop is *invisibility*: at any
horizon N the engine must emit streams bit-identical to ``decode_steps=1``
for every servable family, truncate exactly at a mid-loop EOS (iterations
k+1..N of a dispatch must never leak into a stream), replay token-identically
when a preemption lands between multi-step dispatches, and keep the
sanitizer's allocator invariants (pages freed exactly once). Parity runs in
fp32, like the cross-engine sampled-parity tests: bf16's reassociated
summation flips near-tied draws of random-init smoke models, which is
rounding noise, not loop divergence.

tp=2 parity runs in a subprocess with forced host devices (the pattern
``test_sharding.py`` established), so it executes in the plain tier-1 run
too; the ``tier1-multidevice`` CI job additionally runs this whole file
in-process under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""
import dataclasses
import subprocess
import sys
from functools import lru_cache
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.analysis.recompile import FAMILY_ARCHS, audit_family
from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import ContinuousEngine, Request
from repro.serving.sampling import SamplingParams

ROOT = Path(__file__).resolve().parents[1]


@lru_cache(maxsize=None)
def _fp32_model(name):
    arch = smoke_config(name)
    arch = dataclasses.replace(arch, dtype="float32", param_dtype="float32")
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    return arch, model, params


def _requests(arch, n=4, seed=7):
    """Mixed greedy / sampled / filtered traffic with ragged lengths."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        prompt = list(map(int, rng.integers(5, arch.vocab_size,
                                            int(rng.integers(6, 18)))))
        sp = (SamplingParams(),
              SamplingParams(temperature=0.8, seed=100 + i),
              SamplingParams(temperature=0.9, top_k=8, top_p=0.9,
                             seed=200 + i))[i % 3]
        reqs.append(Request(uid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(4, 9)),
                            sampling=sp))
    return reqs


def _serve(model, params, reqs, *, decode_steps, **kw):
    """One engine run with the sanitizer ON (every completion re-checks the
    allocator conservation + refcount invariants, so a page freed twice by
    the multi-step resync fails here, not in a later test)."""
    defaults = dict(num_slots=3, num_pages=64, page_size=4, max_seq_len=64,
                    prefix_cache=False, sanitize=True)
    defaults.update(kw)
    engine = ContinuousEngine(model, params, decode_steps=decode_steps,
                              **defaults)
    res = engine.run(list(reqs))
    return engine, {uid: r["tokens"] for uid, r in res.items()}


# ------------------------------------------------------------------- parity ----

@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_multistep_parity_all_families(family):
    """Streams bit-identical between decode_steps=1 and N>1 for every
    servable family — and the loop must actually amortize dispatches
    (decode_dispatches < decode steps), or it is an expensive no-op."""
    arch, model, params = _fp32_model(FAMILY_ARCHS[family])
    reqs = _requests(arch)
    e1, ref = _serve(model, params, reqs, decode_steps=1)
    assert e1.decode_dispatches == e1.steps      # N=1: one step per dispatch
    for n in ((4, 16) if family == "dense" else (4,)):
        en, toks = _serve(model, params, reqs, decode_steps=n)
        assert toks == ref, f"{family} diverged at decode_steps={n}"
        assert en.decode_dispatches < en.steps, \
            f"{family} N={n}: loop never ran more than one iteration"


# ------------------------------------------------------------- EOS mid-loop ----

def test_eos_mid_loop_truncates_and_frees_once():
    """A slot hitting EOS on loop iteration k < N: iterations k+1..N must
    not be visible in its stream, the dispatch must report the eos exit,
    and (sanitizer on) its pages are freed exactly once."""
    arch, model, params = _fp32_model("llama3.2-3b")
    rng = np.random.default_rng(23)
    prompt = list(map(int, rng.integers(5, arch.vocab_size, 9)))
    # sampled, not greedy: the smoke model's greedy stream collapses to one
    # repeated token, which never yields a usable first-occurrence EOS id
    base = Request(uid=0, prompt=prompt, max_new_tokens=24,
                   sampling=SamplingParams(temperature=1.0, seed=23))
    _, ref = _serve(model, params, [base], decode_steps=16)
    stream = ref[0]
    # pick an EOS id whose FIRST occurrence is a decode token (index >= 2:
    # index 0 is emitted by the final prefill chunk, not the loop) that
    # lands strictly inside the 16-step horizon
    eos, k = next(((t, i) for i, t in enumerate(stream)
                   if 2 <= i <= 14 and stream.index(t) == i), (None, None))
    assert eos is not None, f"no mid-horizon token to use as EOS: {stream}"
    e, toks = _serve(model, params,
                     [dataclasses.replace(base, eos_id=eos)],
                     decode_steps=16)
    assert toks[0] == stream[:k + 1], \
        "EOS truncation diverged from the unbounded stream"
    assert toks[0][-1] == eos and eos not in toks[0][:-1]
    assert e.decode_exits["eos"] == 1
    assert e.decode_dispatches == 1 and e.steps == k, \
        "EOS within the first horizon must cost exactly one dispatch"
    # drained engine holds nothing: pages freed exactly once, all returned
    assert e.pages_in_use == 0


# ----------------------------------------------- preemption between dispatches -

def _forced_preempt_engine(model, params, *, uid, when, **kw):
    """Engine whose scheduler force-preempts request ``uid`` once, the first
    time ``when(seq)`` holds (simulated pool pressure, deterministic) —
    the pattern ``test_sampling.py`` established."""
    engine = ContinuousEngine(model, params, **kw)
    sched = engine.scheduler
    orig = sched.ensure_capacity
    fired = []

    def forced():
        out = orig()
        victim = next((s for s in sched.running.values()
                       if s.request.uid == uid), None)
        if not fired and victim is not None and not victim.done \
                and len(sched.running) > 1 and when(victim):
            sched._preempt(victim)
            out.append(victim)
            fired.append(victim.request.uid)
        return out

    sched.ensure_capacity = forced
    return engine, fired


def test_preemption_between_multistep_dispatches_replays_identically():
    """A forced preemption landing between multi-step dispatches (the victim
    already holds several loop-emitted tokens) must replay token-identically
    vs an unpreempted decode_steps=1 run: forced replay re-derives every
    PRNG key from the stream position, so the horizon is token-invisible."""
    arch, model, params = _fp32_model("llama3.2-3b")
    reqs = _requests(arch, seed=29)
    reqs = [dataclasses.replace(r, max_new_tokens=max(r.max_new_tokens, 8))
            for r in reqs]
    _, ref = _serve(model, params, reqs, decode_steps=1)
    kw = dict(num_slots=3, num_pages=64, page_size=4, max_seq_len=64,
              prefix_cache=False, sanitize=True, decode_steps=4)
    engine, fired = _forced_preempt_engine(
        model, params, uid=1, when=lambda seq: len(seq.generated) >= 3, **kw)
    res = engine.run(list(reqs))
    assert fired == [1], "forced preemption must actually fire"
    assert {uid: r["tokens"] for uid, r in res.items()} == ref, \
        "preempted+resumed multi-step stream diverged from N=1"


# -------------------------------------------------------- dispatch accounting --

def test_dispatch_accounting_and_exit_reasons():
    """Host dispatches per decode-emitted token fall under the bench's
    1.1/N bound on plain traffic, and the exit-reason counters record why
    each dispatch returned (budget exits for every finishing slot, horizon
    exits for full-length dispatches with no event)."""
    arch, model, params = _fp32_model("llama3.2-3b")
    rng = np.random.default_rng(31)
    reqs = [Request(uid=i,
                    prompt=list(map(int, rng.integers(5, arch.vocab_size,
                                                      10))),
                    max_new_tokens=12)
            for i in range(4)]
    e1, ref = _serve(model, params, reqs, decode_steps=1, num_slots=4)
    e4, toks = _serve(model, params, reqs, decode_steps=4, num_slots=4)
    assert toks == ref
    # each request's first token comes from its final prefill chunk
    decode_tokens = sum(len(v) for v in toks.values()) - len(reqs)
    assert e4.decode_dispatches / decode_tokens < 1.1 / 4
    assert e4.decode_exits["token_budget"] >= 1   # every request ends on it
    assert e4.decode_exits["horizon"] >= 1        # 12 tokens span >1 horizon
    assert e4.decode_exits["eos"] == 0
    assert e1.decode_exits == {"eos": 0, "token_budget": 0,
                               "page_budget": 0, "horizon": 0}, \
        "N=1 keeps the single-step path: no loop, no exit accounting"


# ------------------------------------------------------------- audit closure ---

def test_recompile_audit_covers_multistep_variants():
    """decode_steps=4 re-keys every decode variant on the horizon (key arity
    6 — the trailing elements are the fused-decode flag then N) and the jit
    cache still closes: steps 2..N of the audit trace add zero traces."""
    report = audit_family("dense", decode_steps=4)
    decode_keys = [k for k in report.variants if k and k[0] == "decode"]
    assert decode_keys, "audit trace exercised no decode variant"
    assert all(len(k) == 6 and k[-1] == 4 for k in decode_keys), decode_keys
    # prefill variants must not be re-keyed by the decode horizon: their key
    # set is identical to what the same trace produces at N=1
    ref = audit_family("dense", decode_steps=1)
    prefill = lambda r: {k for k in r.variants if k and k[0] == "prefill"}
    assert prefill(report) == prefill(ref), \
        (prefill(report), prefill(ref))


# ------------------------------------------------------------------ tp parity --

def _run_subprocess(body: str):
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'\n"
        "os.environ['JAX_PLATFORMS'] = 'cpu'\n" + body)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=ROOT, timeout=500,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    return r.stdout


def test_tp2_multistep_parity():
    """Mixed traffic token-identical between (tp=1, N=1) and (tp=2, N∈{4,16}):
    the while_loop carries replicated control state over the sharded pools,
    so the horizon composes with head-sharded TP without divergence."""
    out = _run_subprocess(r"""
import dataclasses
import jax, numpy as np
from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import ContinuousEngine, Request
from repro.serving.sampling import SamplingParams

arch = dataclasses.replace(smoke_config("llama3.2-3b"), num_kv_heads=4,
                           dtype="float32", param_dtype="float32")
model = build_model(arch)
params = model.init(jax.random.key(0))
rng = np.random.default_rng(11)
prompts = [list(map(int, rng.integers(5, arch.vocab_size, 10)))
           for _ in range(4)]

def serve(tp, n):
    reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=8,
                    sampling=(SamplingParams(temperature=0.8, top_k=8,
                                             seed=50 + i)
                              if i % 2 else SamplingParams()))
            for i in range(4)]
    engine = ContinuousEngine(model, params, num_slots=3, num_pages=48,
                              page_size=4, max_seq_len=48,
                              prefix_cache=False, tp=tp, decode_steps=n)
    res = engine.run(reqs)
    return {uid: r["tokens"] for uid, r in res.items()}

ref = serve(1, 1)
assert serve(2, 4) == ref, "tp=2 N=4 diverged"
assert serve(2, 16) == ref, "tp=2 N=16 diverged"
print("TP-MULTISTEP-OK")
""")
    assert "TP-MULTISTEP-OK" in out
