"""Attention: chunked-vs-naive equivalence (fwd + custom-VJP bwd), GQA, RoPE,
M-RoPE text-degeneration, decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    given = settings = st = None

from repro.models import attention as A
from repro.models.layers import apply_mrope, apply_rope


def _qkv(key, b, sq, sk, hq, hkv, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return (jax.random.normal(k1, (b, sq, hq, d), dtype),
            jax.random.normal(k2, (b, sk, hkv, d), dtype),
            jax.random.normal(k3, (b, sk, hkv, d), dtype))


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 16)])
def test_chunked_matches_naive_fwd_bwd(causal, window):
    q, k, v = _qkv(jax.random.key(0), 2, 32, 64, 8, 4, 16)

    def loss_naive(q, k, v):
        return (A.naive_attention(q, k, v, causal=causal,
                                  window=window) ** 2).sum()

    def loss_chunk(q, k, v):
        return (A.chunked_attention(q, k, v, causal=causal, chunk=16,
                                    window=window) ** 2).sum()

    o1 = A.naive_attention(q, k, v, causal=causal, window=window)
    o2 = A.chunked_attention(q, k, v, causal=causal, chunk=16, window=window)
    np.testing.assert_allclose(o1, o2, atol=2e-5)
    g1 = jax.grad(loss_naive, (0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_chunk, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=5e-5)


if st is not None:
    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 3),
        hkv=st.sampled_from([1, 2, 4]),
        g=st.sampled_from([1, 2, 3]),
        d=st.sampled_from([8, 16]),
        chunk=st.sampled_from([8, 16, 32]),
        causal=st.booleans(),
    )
    def test_chunked_property_sweep(b, hkv, g, d, chunk, causal):
        """Hypothesis sweep over GQA shapes/chunks: chunked == naive."""
        sq = sk = 32
        q, k, v = _qkv(jax.random.key(b * 7 + d), b, sq, sk, hkv * g, hkv, d)
        o1 = A.naive_attention(q, k, v, causal=causal)
        o2 = A.chunked_attention(q, k, v, causal=causal, chunk=chunk)
        np.testing.assert_allclose(o1, o2, atol=3e-5)
else:
    def test_chunked_property_sweep():
        pytest.importorskip("hypothesis")


def test_kv_len_masking():
    q, k, v = _qkv(jax.random.key(1), 2, 4, 32, 4, 4, 8)
    kv_len = jnp.array([10, 32])
    o_full = A.naive_attention(q, k[:, :10], v[:, :10], causal=False)
    o_mask = A.naive_attention(q, k, v, causal=False, kv_len=kv_len)
    np.testing.assert_allclose(o_full[0], o_mask[0], atol=1e-5)


def test_rope_relative_shift_invariance():
    """RoPE scores depend only on relative positions."""
    d = 16
    q = jax.random.normal(jax.random.key(0), (1, 8, 2, d))
    k = jax.random.normal(jax.random.key(1), (1, 8, 2, d))
    p0 = jnp.arange(8)[None]
    s0 = jnp.einsum("bqhd,bkhd->bhqk", apply_rope(q, p0, 1e4),
                    apply_rope(k, p0, 1e4))
    s1 = jnp.einsum("bqhd,bkhd->bhqk", apply_rope(q, p0 + 100, 1e4),
                    apply_rope(k, p0 + 100, 1e4))
    np.testing.assert_allclose(s0, s1, atol=1e-3)


def test_mrope_degenerates_to_rope_for_text():
    d = 16
    x = jax.random.normal(jax.random.key(0), (2, 8, 2, d))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    thw = jnp.broadcast_to(pos[None], (3, 2, 8))
    np.testing.assert_allclose(apply_rope(x, pos, 1e4),
                               apply_mrope(x, thw, 1e4), atol=1e-5)


def test_flash_kernel_interpret_matches_naive():
    from repro.kernels.flash_attention import ops as fops
    q, k, v = _qkv(jax.random.key(3), 2, 128, 128, 4, 2, 64)
    kv_len = jnp.array([100, 128])
    for causal in (True, False):
        o_k = fops.flash_attention(q, k, v, causal=causal, kv_len=kv_len,
                                   block_kv=64, interpret=True)
        o_r = A.naive_attention(q, k, v, causal=causal, kv_len=kv_len)
        np.testing.assert_allclose(o_k, o_r, atol=2e-5)
