"""GPipe pipeline parallelism: 4-stage pipeline == sequential (8 host devices)."""
import subprocess
import sys
from pathlib import Path

from repro.parallel.pipeline import bubble_fraction

ROOT = Path(__file__).resolve().parents[1]


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == 3 / 7
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 28) < 0.1


def test_pipeline_matches_sequential_multidevice():
    script = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
os.environ['JAX_PLATFORMS'] = 'cpu'
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.pipeline import pipeline_apply
from repro.parallel.sharding import shard_map
from repro.launch.mesh import make_mesh
S, M, B, D = 4, 8, 16, 32
mesh = make_mesh((S,), ("pipe",))
ws = jax.random.normal(jax.random.key(0), (S, D, D)) * 0.3
def stage_fn(w, x): return jnp.tanh(x @ w)
def run(ws_local, x):
    return pipeline_apply(stage_fn, ws_local[0], x, num_stages=S, num_micro=M)
x = jax.random.normal(jax.random.key(1), (B, D))
y = jax.jit(shard_map(run, mesh=mesh, in_specs=(P("pipe"), P()),
                      out_specs=P()))(ws, x)
ref = x
for s in range(S): ref = jnp.tanh(ref @ ws[s])
err = float(jnp.max(jnp.abs(y - ref)))
assert err < 1e-6, err
print("PIPELINE_OK", err)
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=ROOT, timeout=400,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, (r.stdout[-800:], r.stderr[-2500:])
    assert "PIPELINE_OK" in r.stdout
