"""Paged decode-attention: Pallas kernel (interpret) vs pure-JAX ref vs a
direct dense computation, across GQA ratios, page sizes, ragged lengths, and
a fragmented page table."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import kernel, ref
from repro.models.attention import naive_attention


def _paged_case(seed, b, hq, hkv, d, page_size, num_pages, max_pages,
                seq_lens, dtype=jnp.float32):
    """Random q + pools; page table fragmented (shuffled physical ids)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, hq, d)), dtype)
    k_pages = jnp.asarray(rng.normal(size=(num_pages, page_size, hkv, d)),
                          dtype)
    v_pages = jnp.asarray(rng.normal(size=(num_pages, page_size, hkv, d)),
                          dtype)
    ids = rng.permutation(np.arange(1, num_pages))[:b * max_pages]
    page_table = jnp.asarray(ids.reshape(b, max_pages).astype(np.int32))
    return q, k_pages, v_pages, page_table, jnp.asarray(seq_lens, jnp.int32)


@pytest.mark.parametrize("page_size,hq,hkv", [(4, 4, 1), (8, 4, 2),
                                              (16, 4, 4), (8, 6, 2)])
def test_kernel_matches_ref(page_size, hq, hkv):
    max_pages = 4
    case = _paged_case(0, 3, hq, hkv, 16, page_size, 16, max_pages,
                       seq_lens=[1, page_size * 2 + 3, page_size * max_pages])
    o_ref = ref.paged_decode_attention(*case)
    o_k = kernel.paged_decode_attention_fwd(*case, interpret=True)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref), atol=1e-5)


def test_kernel_zeroes_inactive_slots():
    case = _paged_case(1, 4, 4, 2, 8, 8, 12, 2, seq_lens=[5, 0, 9, 0])
    o_k = kernel.paged_decode_attention_fwd(*case, interpret=True)
    assert float(jnp.max(jnp.abs(o_k[1]))) == 0.0
    assert float(jnp.max(jnp.abs(o_k[3]))) == 0.0
    assert float(jnp.max(jnp.abs(o_k[0]))) > 0.0


def test_ref_matches_dense_gather():
    """The paged ref == dense attention over the same logical K/V rows."""
    b, hq, hkv, d, page, maxp = 2, 4, 2, 16, 4, 3
    q, kp, vp, pt, sl = _paged_case(2, b, hq, hkv, d, page, 16, maxp,
                                    seq_lens=[7, 11])
    o_paged = ref.paged_decode_attention(q, kp, vp, pt, sl)
    # densify: walk the page table row by row
    k = np.zeros((b, maxp * page, hkv, d), np.float32)
    v = np.zeros_like(k)
    for i in range(b):
        for j in range(maxp):
            k[i, j * page:(j + 1) * page] = np.asarray(kp)[int(pt[i, j])]
            v[i, j * page:(j + 1) * page] = np.asarray(vp)[int(pt[i, j])]
    o_dense = naive_attention(q[:, None], jnp.asarray(k), jnp.asarray(v),
                              causal=False, kv_len=sl)[:, 0]
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_dense),
                               atol=1e-6)


def test_kernel_fragmented_vs_contiguous_equivalence():
    """Physical placement must not matter: the same logical K/V served from a
    contiguous table and from a scattered one give identical outputs."""
    b, hq, hkv, d, page, maxp, P = 2, 4, 2, 8, 4, 3, 16
    rng = np.random.default_rng(5)
    rows_k = rng.normal(size=(b, maxp * page, hkv, d)).astype(np.float32)
    rows_v = rng.normal(size=(b, maxp * page, hkv, d)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    sl = jnp.asarray([9, 12], jnp.int32)

    def build(assignment):
        kp = np.zeros((P, page, hkv, d), np.float32)
        vp = np.zeros_like(kp)
        pt = np.zeros((b, maxp), np.int32)
        for i in range(b):
            for j in range(maxp):
                pid = assignment[i][j]
                kp[pid] = rows_k[i, j * page:(j + 1) * page]
                vp[pid] = rows_v[i, j * page:(j + 1) * page]
                pt[i, j] = pid
        return (jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(pt))

    contiguous = build([[1, 2, 3], [4, 5, 6]])
    fragmented = build([[11, 3, 7], [14, 1, 9]])
    o1 = kernel.paged_decode_attention_fwd(q, *contiguous, sl, interpret=True)
    o2 = kernel.paged_decode_attention_fwd(q, *fragmented, sl, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=0)


# --------------------------------------------------------------- paged prefill ---

def _prefill_case(seed, hq, hkv, d, page_size, num_pages, max_pages, chunk,
                  dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(chunk, hq, d)), dtype)
    k_pages = jnp.asarray(rng.normal(size=(num_pages, page_size, hkv, d)),
                          dtype)
    v_pages = jnp.asarray(rng.normal(size=(num_pages, page_size, hkv, d)),
                          dtype)
    row = rng.permutation(np.arange(1, num_pages))[:max_pages]
    return q, k_pages, v_pages, jnp.asarray(row.astype(np.int32))


@pytest.mark.parametrize("page_size,hq,hkv,start,valid",
                         [(4, 4, 2, 0, 8),     # aligned, full chunk
                          (4, 4, 1, 4, 5),     # one cached page behind
                          (8, 6, 2, 3, 4),     # unaligned start (CoW tail)
                          (4, 4, 4, 8, 2)])    # mostly-padded chunk
def test_prefill_kernel_matches_ref(page_size, hq, hkv, start, valid):
    """Chunked-prefill kernel == gather ref on every valid row, for aligned
    and mid-page (post-CoW) chunk starts."""
    chunk, maxp = 8, 5
    q, kp, vp, row = _prefill_case(0, hq, hkv, 16, page_size, 24, maxp, chunk)
    total = start + valid
    o_ref = ref.paged_prefill_attention(q, kp, vp, row, start, total)
    o_k = kernel.paged_prefill_attention_fwd(q, kp, vp, row, start, total,
                                             interpret=True)
    np.testing.assert_allclose(np.asarray(o_k)[:valid],
                               np.asarray(o_ref)[:valid], atol=1e-5)


def test_prefill_ref_matches_dense_gather():
    """Causal chunk rows == dense attention over the same logical K/V with
    the chunk offset folded into the causal mask."""
    chunk, page, maxp, hq, hkv, d, start, valid = 6, 4, 4, 4, 2, 8, 4, 6
    q, kp, vp, row = _prefill_case(1, hq, hkv, d, page, 16, maxp, chunk)
    total = start + valid
    o_paged = ref.paged_prefill_attention(q, kp, vp, row, start, total)
    k = np.asarray(kp)[np.asarray(row)].reshape(1, -1, hkv, d)
    v = np.asarray(vp)[np.asarray(row)].reshape(1, -1, hkv, d)
    o_dense = naive_attention(q[None], jnp.asarray(k), jnp.asarray(v),
                              causal=True, q_offset=start,
                              kv_len=jnp.asarray([total], jnp.int32))[0]
    np.testing.assert_allclose(np.asarray(o_paged)[:valid],
                               np.asarray(o_dense)[:valid], atol=1e-6)


def test_prefill_kernel_first_chunk_sees_only_itself():
    """start == 0: row i attends to rows <= i regardless of stale page
    content past the chunk (kv_len masking)."""
    chunk, page, maxp, hq, hkv, d = 4, 4, 3, 4, 2, 8
    q, kp, vp, row = _prefill_case(2, hq, hkv, d, page, 12, maxp, chunk)
    o_k = kernel.paged_prefill_attention_fwd(q, kp, vp, row, 0, chunk,
                                             interpret=True)
    # row 0 can see exactly one K/V row -> output == that row's v (per head)
    v0 = np.asarray(vp)[int(row[0]), 0]                     # [hkv, d]
    expect = np.repeat(v0, hq // hkv, axis=0)               # GQA broadcast
    np.testing.assert_allclose(np.asarray(o_k)[0], expect, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    case = _paged_case(3, 2, 4, 2, 16, 8, 12, 3, seq_lens=[6, 20],
                      dtype=dtype)
    o_ref = ref.paged_decode_attention(*case)
    o_k = kernel.paged_decode_attention_fwd(*case, interpret=True)
    atol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_ref, np.float32), atol=atol)
