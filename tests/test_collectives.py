"""Compressed + hierarchical collectives (8 host devices, subprocess)."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel import collectives as C

ROOT = Path(__file__).resolve().parents[1]


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.key(0), (1024,)) * 3.0
    q, s = C.quantize_int8(x)
    err = np.abs(np.asarray(C.dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_converges():
    """With error feedback the *accumulated* compressed sum tracks the true sum."""
    x = jax.random.normal(jax.random.key(1), (512,))
    err = jnp.zeros_like(x)
    acc_q = jnp.zeros_like(x)
    for _ in range(20):
        x32 = x + err
        q, s = C.quantize_int8(x32)
        deq = C.dequantize_int8(q, s)
        err = x32 - deq
        acc_q = acc_q + deq
    np.testing.assert_allclose(acc_q / 20, x, atol=float(s))


def test_compressed_and_hierarchical_psum_multidevice():
    script = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
os.environ['JAX_PLATFORMS'] = 'cpu'
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel import collectives as C
from repro.parallel.sharding import shard_map
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("pod", "data"))
x = jax.random.normal(jax.random.key(0), (256,))  # a model-sized flat grad

def f(g):
    # per-(pod,data)-shard distinct gradient: g * (1 + data_idx + 10*pod_idx)
    local = g * (1.0 + jax.lax.axis_index("data")
                 + 10.0 * jax.lax.axis_index("pod"))
    y, err = C.compressed_psum(local, "data")
    h = C.hierarchical_psum(local, "data", "pod")
    return y, err, h

y, err, h = jax.jit(shard_map(
    f, mesh=mesh, in_specs=(P(),),
    out_specs=(P(("pod", "data")), P(("pod", "data")), P(("pod", "data")))))(x)
# compressed mean over data within pod 0: mean(1..4)*x = 2.5x
# each shard's local output is the full 256-vector; global stacks 8 of them
y0 = y.reshape(8, -1)[0]
scale = 4 * float(jnp.max(jnp.abs(x))) / 127.0
assert float(jnp.max(jnp.abs(y0 - 2.5 * x))) < 10 * scale + 0.05
# hierarchical = full sum over all 8 shards: sum over pods/data of factors
# = sum_{p,d} (1 + d + 10p) = 8 + 2*(0+1+2+3) + 4*10 = 60 -> 60*x
h_full = h.reshape(8, -1)[0]
np.testing.assert_allclose(np.asarray(h_full), np.asarray(60.0 * x),
                           rtol=1e-3, atol=1e-3)
print("COLLECTIVES_OK")
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=ROOT, timeout=400,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, (r.stdout[-800:], r.stderr[-2500:])
    assert "COLLECTIVES_OK" in r.stdout
