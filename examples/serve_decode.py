"""Serving example: the continuous-batching engine (paged KV cache) next to
the original static-batch driver, on the same prompts.

    PYTHONPATH=src python examples/serve_decode.py [arch-id]

Both runs print their generations — greedy decode makes them identical; the
continuous engine admits each request separately and recycles slots/pages as
sequences finish (see README §Serving engine).
"""
import sys

from repro.launch import serve

arch = sys.argv[1] if len(sys.argv) > 1 else "qwen2-vl-2b"
common = ["--arch", arch, "--smoke", "--batch", "4",
          "--prompt-len", "32", "--gen-len", "16"]
serve.main(common + ["--engine", "static"])
serve.main(common + ["--engine", "continuous", "--page-size", "8"])
