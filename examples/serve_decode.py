"""Batched serving example: prefill + token-by-token decode with a KV cache.

    PYTHONPATH=src python examples/serve_decode.py [arch-id]
"""
import sys

from repro.launch import serve

arch = sys.argv[1] if len(sys.argv) > 1 else "qwen2-vl-2b"
serve.main(["--arch", arch, "--smoke", "--batch", "4",
            "--prompt-len", "32", "--gen-len", "16"])
