"""The paper's methodology as a 20-line user script: characterize any arch.

Prints the Table-3 GEMM inventory, the Fig-8 arithmetic-intensity table and a
Fig-4-style runtime breakdown for a chosen (arch, batch, seq) on TPU v5e.

    PYTHONPATH=src python examples/characterize_arch.py [arch-id] [batch] [seq]
"""
import sys

from repro.configs import get_config
from repro.core import analytical
from repro.core.roofline import V5E

arch = get_config(sys.argv[1] if len(sys.argv) > 1 else "bert-large")
batch = int(sys.argv[2]) if len(sys.argv) > 2 else 32
seq = int(sys.argv[3]) if len(sys.argv) > 3 else 128

print(f"=== {arch.name}: GEMM inventory (fwd), B={batch} n={seq} ===")
print(f"{'name':16s} {'layer':12s} {'M':>7s} {'N':>9s} {'K':>7s} {'batch':>7s} "
      f"{'GFLOPs':>9s} {'ops/byte':>9s}")
for g in analytical.transformer_gemms(arch, batch, seq, "fwd"):
    print(f"{g.name:16s} {g.layer:12s} {g.m:7d} {g.n:9d} {g.k:7d} "
          f"{g.batch:7d} {g.flops/1e9:9.1f} {g.intensity():9.1f}")

print(f"\n=== non-GEMM phases (Fig 8) ===")
print(f"{'name':26s} {'layer':14s} {'GFLOPs':>9s} {'GB':>8s} {'ops/byte':>9s}")
for e in analytical.nongemm_ops(arch, batch, seq):
    print(f"{e.name:26s} {e.layer:14s} {e.total_flops/1e9:9.2f} "
          f"{e.total_bytes/1e9:8.2f} {e.intensity:9.2f}")

print(f"\n=== runtime breakdown on {V5E.name} (train step) ===")
times = analytical.phase_times(arch, batch, seq, dev=V5E)
total = sum(times.values())
for k, v in sorted(times.items(), key=lambda kv: -kv[1]):
    print(f"  {k:14s} {v*1e3:9.3f} ms  {100*v/total:5.1f}%")
print(f"  {'TOTAL':14s} {total*1e3:9.3f} ms")
