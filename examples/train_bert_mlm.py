"""End-to-end driver: pre-train a ~100M-param BERT on synthetic MLM data for a
few hundred steps with LAMB, checkpointing + resuming — the paper's workload.

    PYTHONPATH=src python examples/train_bert_mlm.py [--steps 300]
"""
import argparse
import dataclasses
import sys

sys.argv = [sys.argv[0]]  # re-parse below via repro.launch.train

from repro.launch import train as train_mod  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args, _ = ap.parse_known_args()
    # bert-base-ish: 12L x 768 ~ 110M params — the "~100M for a few hundred
    # steps" end-to-end deliverable
    out = train_mod.main([
        "--arch", "bert-large", "--batch", "16", "--seq", "128",
        "--steps", str(args.steps), "--optimizer", "lamb",
        "--ckpt-dir", "/tmp/repro_bert_ckpt", "--ckpt-every", "100",
    ])
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0], "MLM loss must decrease"


if __name__ == "__main__":
    main()
