"""Quickstart: build any assigned architecture, run a train step + a decode step.

    PYTHONPATH=src python examples/quickstart.py [arch-id]
"""
import sys

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, ShapeConfig, smoke_config
from repro.models import build_model
from repro.train.steps import build_train_step

arch_name = sys.argv[1] if len(sys.argv) > 1 else "llama3.2-3b"
arch = smoke_config(arch_name)               # reduced config: runs on CPU
print(f"arch: {arch.name} ({arch.family}), "
      f"{arch.param_count()/1e6:.1f}M params (reduced)")

# --- one training step ---------------------------------------------------------
shape = ShapeConfig("quickstart", seq_len=64, global_batch=4, kind="train")
run = RunConfig(arch=arch, shape=shape, zero1=False)
bundle = build_train_step(run)
state = bundle.init(seed=0)
tokens = jax.random.randint(jax.random.key(1), (4, 64), 5, arch.vocab_size)
batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1),
         "loss_mask": jnp.ones((4, 64), jnp.bfloat16)}
if arch.family == "encdec":
    batch["frontend_embeddings"] = jnp.zeros((4, arch.enc_seq_len,
                                              arch.d_model), jnp.bfloat16)
state, metrics = jax.jit(bundle.fn)(state, batch)
print(f"train step: loss={float(metrics['loss']):.4f} "
      f"grad_norm={float(metrics['grad_norm']):.3f}")

# --- one decode step -----------------------------------------------------------
if not arch.bidirectional:
    model = build_model(arch)
    params = jax.tree.map(lambda p: p.astype(jnp.bfloat16),
                          model.init(jax.random.key(0)))
    caches = model.init_caches(None, 4, 128)
    logits, caches = jax.jit(model.prefill)(params, caches,
                                            {k: v for k, v in batch.items()
                                             if k in ("tokens",
                                                      "frontend_embeddings")})
    step = {"tokens": jnp.argmax(logits[:, -1:], -1),
            "positions": jnp.full((4,), 64, jnp.int32)}
    logits, caches = jax.jit(model.decode_step)(params, caches, step)
    print(f"decode step: next-token logits {logits.shape}, "
          f"finite={bool(jnp.isfinite(logits).all())}")
print("quickstart OK")
