"""LAMB optimizer — faithful to the paper's Figure 3, with fused-kernel and ZeRO paths.

Two-stage structure (the paper's characterization target, Takeaways 2/3/8):

  global:     g' = || g(i) ||_2                      (all-model gradient 2-norm —
                                                      serializes update vs backprop)
  Stage 1     ĝ  = g / g'
  (per layer) m  = β1 m + (1-β1) ĝ
              v  = β2 v + (1-β2) ĝ²
              m̂  = m / (1-β1^t);  v̂ = v / (1-β2^t)
              u  = m̂ / (√v̂ + ε) + γ w
  2-norms     w' = ||w_l||;  u' = ||u_l||            (per layer)
  Stage 2     r  = w'/u';  w ← w - λ r u

The memory character the paper measures — reads w, g, m, v + writes w, m, v ≈ 4x
model size of traffic for ~10 flops/element — is preserved; the Pallas
``fused_lamb`` kernel (kernels/fused_lamb) fuses Stage 1+2 into one HBM pass.

``layer_axes`` marks leaves with a leading scan-stacked layer dim so trust ratios
stay *per layer* exactly as in Fig 3.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import zero

PyTree = Any


@dataclasses.dataclass(frozen=True)
class LambConfig:
    learning_rate: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-6
    weight_decay: float = 0.01
    zero1: bool = True
    pad_multiple: int = 256            # device count: flat states shard evenly
    use_fused_kernel: bool = False     # route stage1+2 through the Pallas kernel
    # mixed precision (paper §3.2.1): bf16 params in the model, fp32 master copy
    # here — "LAMB updates are computed using single precision copies" (Takeaway 3)
    master_weights: bool = True
    # beyond-paper: bf16 m/v halves the optimizer's 4x-model-size HBM traffic
    # (Takeaway 8) at the cost of update precision
    state_dtype: str = "float32"


def _layer_axes(params: PyTree) -> PyTree:
    """Number of leading 'row' axes per leaf: the scan-stacked layer dim (+1)
    and the MoE expert dim (+1) — trust ratios are per (layer, expert) row and
    the expert dim keeps its model-axis sharding inside the optimizer state."""
    def mark(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", None))) for k in path]
        z = 0
        if ("blocks" in names and leaf.ndim >= 2
                and not any(n.startswith("period_") for n in names)):
            z += 1
        if "experts" in names[:-1] and leaf.ndim >= z + 2:
            z += 1
        return z
    return jax.tree_util.tree_map_with_path(mark, params)


def init(cfg: LambConfig, params: PyTree) -> PyTree:
    la = _layer_axes(params)
    sdt = jnp.dtype(cfg.state_dtype)
    if cfg.zero1:
        def zeros(p, z):
            return jnp.zeros(
                zero.flatten_leaf(p, z, cfg.pad_multiple).shape, sdt)

        def master(p, z):
            return zero.flatten_leaf(p, z, cfg.pad_multiple)
    else:
        def zeros(p, z):
            return jnp.zeros(p.shape, sdt)

        def master(p, z):
            return p.astype(jnp.float32)
    state = {
        "m": jax.tree.map(zeros, params, la),
        "v": jax.tree.map(zeros, params, la),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_weights:
        state["master"] = jax.tree.map(master, params, la)
    return state


def _stage12(w32, g, m, v, *, ginv, c1, c2, cfg: LambConfig, red_axes,
             valid_mask=None):
    """Fig 3 math on one leaf. red_axes: axes of one 'layer' slice."""
    if cfg.use_fused_kernel:
        from ..kernels.fused_lamb import ops as fused
        return fused.lamb_stage12(w32, g, m, v, ginv=ginv, c1=c1, c2=c2,
                                  beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps,
                                  weight_decay=cfg.weight_decay,
                                  lr=cfg.learning_rate, red_axes=red_axes)
    gn = g.astype(jnp.float32) * ginv
    m_new = cfg.beta1 * m + (1.0 - cfg.beta1) * gn
    v_new = cfg.beta2 * v + (1.0 - cfg.beta2) * jnp.square(gn)
    m_hat = m_new * c1
    v_hat = v_new * c2
    u = m_hat / (jnp.sqrt(v_hat) + cfg.eps) + cfg.weight_decay * w32
    if valid_mask is not None:
        u = u * valid_mask
    wn = jnp.sqrt(jnp.sum(jnp.square(w32), axis=red_axes, keepdims=True))
    un = jnp.sqrt(jnp.sum(jnp.square(u), axis=red_axes, keepdims=True))
    r = jnp.where((wn > 0) & (un > 0), wn / jnp.maximum(un, 1e-30), 1.0)
    w_new = w32 - cfg.learning_rate * r * u
    return w_new, m_new, v_new


def update(cfg: LambConfig, grads: PyTree, state: PyTree, params: PyTree
           ) -> Tuple[PyTree, PyTree]:
    with jax.named_scope("lamb"):
        return _update(cfg, grads, state, params)


def _update(cfg: LambConfig, grads: PyTree, state: PyTree, params: PyTree
            ) -> Tuple[PyTree, PyTree]:
    la = _layer_axes(params)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 / (1.0 - jnp.power(cfg.beta1, t))
    c2 = 1.0 / (1.0 - jnp.power(cfg.beta2, t))

    # global gradient norm (fp32) — the serializing reduction the paper calls out
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    ginv = 1.0 / jnp.maximum(jnp.sqrt(gsq), 1e-12)

    sdt = jnp.dtype(cfg.state_dtype)
    masters = state.get("master")

    if cfg.zero1:
        def upd(w, g, m, v, mw, z):
            shape, dtype = w.shape, w.dtype
            wf = mw if mw is not None else zero.flatten_leaf(
                w, z, cfg.pad_multiple)
            # grads may arrive pre-flattened (ZeRO-layout accumulation)
            gf = g if g.shape == m.shape else zero.flatten_leaf(
                g, z, cfg.pad_multiple)
            w_new, m_new, v_new = _stage12(
                wf, gf, m.astype(jnp.float32), v.astype(jnp.float32),
                ginv=ginv, c1=c1, c2=c2, cfg=cfg, red_axes=(-1,))
            return (zero.unflatten_leaf(w_new, shape, z, dtype),
                    m_new.astype(sdt), v_new.astype(sdt),
                    w_new if mw is not None else None)
    else:
        def upd(w, g, m, v, mw, z):
            red = tuple(range(z, w.ndim)) if w.ndim > z else (0,)
            w32 = (mw if mw is not None else w).astype(jnp.float32)
            g32 = g.astype(jnp.float32)
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            if w.ndim == 0:
                w32, g32, m32, v32 = (a.reshape(1)
                                      for a in (w32, g32, m32, v32))
                red = (0,)
            w_new, m_new, v_new = _stage12(
                w32, g32, m32, v32, ginv=ginv, c1=c1, c2=c2, cfg=cfg,
                red_axes=red)
            w_new = w_new.reshape(w.shape)
            return (w_new.astype(w.dtype),
                    m_new.reshape(v.shape).astype(sdt),
                    v_new.reshape(v.shape).astype(sdt),
                    w_new if mw is not None else None)

    if masters is None:
        masters = jax.tree.map(lambda _: None, params,
                               is_leaf=lambda x: x is None)
        out = jax.tree.map(lambda w, g, m, v, z: upd(w, g, m, v, None, z),
                           params, grads, state["m"], state["v"], la)
    else:
        out = jax.tree.map(upd, params, grads, state["m"], state["v"],
                           masters, la)

    def pick(i):
        return jax.tree.map(lambda o: o[i], out,
                            is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": pick(1), "v": pick(2), "step": step}
    if "master" in state:
        new_state["master"] = pick(3)
    return pick(0), new_state
