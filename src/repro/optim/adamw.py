"""AdamW — the paper's Fig 13 fusion-comparison optimizer (Adam [+ decoupled decay]).

Same state layout options as LAMB (param-shaped, or ZeRO-1 flat-sharded)."""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from . import zero

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    zero1: bool = True
    pad_multiple: int = 256


def init(cfg: AdamWConfig, params: PyTree) -> PyTree:
    if cfg.zero1:
        def zeros(p):
            return jnp.zeros_like(zero.flatten_leaf(p, 0, cfg.pad_multiple))
    else:
        def zeros(p):
            return jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def update(cfg: AdamWConfig, grads: PyTree, state: PyTree, params: PyTree
           ) -> Tuple[PyTree, PyTree]:
    with jax.named_scope("adamw"):
        return _update(cfg, grads, state, params)


def _update(cfg: AdamWConfig, grads: PyTree, state: PyTree, params: PyTree
            ) -> Tuple[PyTree, PyTree]:
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 / (1.0 - jnp.power(cfg.beta1, t))
    c2 = 1.0 / (1.0 - jnp.power(cfg.beta2, t))

    def upd(w, g, m, v):
        shape, dtype = w.shape, w.dtype
        if cfg.zero1:
            w32 = zero.flatten_leaf(w, 0, cfg.pad_multiple)
            g32 = g if g.shape == m.shape else \
                zero.flatten_leaf(g, 0, cfg.pad_multiple)
        else:
            w32 = w.astype(jnp.float32)
            g32 = g.astype(jnp.float32)
        m_new = cfg.beta1 * m + (1 - cfg.beta1) * g32
        v_new = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g32)
        u = (m_new * c1) / (jnp.sqrt(v_new * c2) + cfg.eps)
        w_new = w32 - cfg.learning_rate * (u + cfg.weight_decay * w32)
        if cfg.zero1:
            w_new = zero.unflatten_leaf(w_new, shape, 0, dtype)
        else:
            w_new = w_new.astype(dtype)
        return w_new, m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    pick = lambda i: jax.tree.map(lambda o: o[i], out,  # noqa: E731
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), {"m": pick(1), "v": pick(2), "step": step}
