"""Gradient utilities: global-norm clipping + micro-batch accumulation (paper §4.2)."""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def accumulate_microbatches(loss_fn: Callable, params: PyTree,
                            batch: Dict[str, jax.Array], num_micro: int,
                            transform: Callable = None
                            ) -> Tuple[PyTree, Dict[str, jax.Array]]:
    """Micro-batching / gradient accumulation (paper §4.2).

    Splits the leading batch dim into ``num_micro`` micro-batches, runs fwd+bwd per
    micro-batch under ``lax.scan`` (one microbatch's activations live at a time) and
    averages gradients — trading the update cost down by the micro-batch count at
    the price of extra elementwise accumulation traffic, exactly the trade-off the
    paper describes.

    ``transform`` (optional) maps per-microbatch grads into an accumulation layout
    before summation — the trainer passes the ZeRO flat/sharded layout so the fp32
    carry is 1/(D*M) per device (ZeRO-2-style gradient sharding) instead of a full
    fp32 model replica.
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    if num_micro == 1:
        (_, metrics), grads = grad_fn(params, batch)
        if transform is not None:
            grads = transform(grads)
        return grads, metrics

    def split(x):
        b = x.shape[0]
        assert b % num_micro == 0, (b, num_micro)
        return x.reshape(num_micro, b // num_micro, *x.shape[1:])

    micro = {k: (split(v) if k != "mrope_positions" else
                 jnp.moveaxis(split(jnp.moveaxis(v, 0, 1)), 2, 1))
             for k, v in batch.items()}

    def body(acc, mb):
        (_, metrics), grads = grad_fn(params, mb)
        if transform is not None:
            grads = transform(grads)
        acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / num_micro, acc, grads)
        return acc, metrics

    if transform is None:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    else:
        acc_struct = jax.eval_shape(
            lambda p: transform(jax.tree.map(jnp.zeros_like, p)), params)
        zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                             acc_struct)
    grads, metrics = jax.lax.scan(body, zeros, micro)
    metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics)
    return grads, metrics
