"""Plain SGD (+momentum) — the minimal-traffic reference point in Fig 8-style
optimizer characterization (reads w,g[,m]; writes w[,m])."""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    learning_rate: float = 1e-2
    momentum: float = 0.9
    zero1: bool = False
    weight_decay: float = 0.0


def init(cfg: SGDConfig, params: PyTree) -> PyTree:
    if cfg.momentum == 0.0:
        return {"step": jnp.zeros((), jnp.int32)}
    return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}


def update(cfg: SGDConfig, grads: PyTree, state: PyTree, params: PyTree
           ) -> Tuple[PyTree, PyTree]:
    def upd(w, g, m):
        g32 = g.astype(jnp.float32) + cfg.weight_decay * w.astype(jnp.float32)
        if m is not None:
            m = cfg.momentum * m + g32
            g32 = m
        return (w.astype(jnp.float32) - cfg.learning_rate * g32).astype(w.dtype), m

    if cfg.momentum == 0.0:
        out = jax.tree.map(lambda w, g: upd(w, g, None)[0], params, grads)
        return out, {"step": state["step"] + 1}
    out = jax.tree.map(upd, params, grads, state["m"])
    pick = lambda i: jax.tree.map(lambda o: o[i], out,  # noqa: E731
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), {"m": pick(1), "step": state["step"] + 1}
