"""ZeRO-1 state layout: parameter leaves stored [*leading, padded_flat].

Leading dims are preserved "row" axes: the scan-stacked layer dim (LAMB's per-layer
trust ratio, paper Fig 3) and — for MoE expert weights — the expert dim, which stays
sharded on the model axis exactly like the parameter itself, so optimizer math never
re-lays out expert tensors (that reshard cost 20+ GB/device of fp32 intermediates on
jamba before this layout). The flat tail is padded to a multiple of the device count
and sharded over the data axis (experts) or (data, model) (everything else); XLA
materializes the ZeRO collectives — reduce-scatter of grads in, all-gather of updated
params out — from the sharding mismatch alone (the paper's cited fix [60] for LAMB's
replicated 4x-model-size traffic).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def pad_to(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


def flatten_leaf(x: jax.Array, z_axes: int, multiple: int) -> jax.Array:
    """x [*lead(z_axes), ...rest] -> [*lead_or_1, padded_flat] fp32."""
    lead = tuple(int(d) for d in x.shape[:z_axes]) if z_axes else (1,)
    flat = x.reshape(*lead, -1).astype(jnp.float32)
    padded = pad_to(flat.shape[-1], multiple)
    if padded != flat.shape[-1]:
        pad_width = [(0, 0)] * (flat.ndim - 1) + [(0, padded - flat.shape[-1])]
        flat = jnp.pad(flat, pad_width)
    return flat


def unflatten_leaf(flat: jax.Array, shape: Tuple[int, ...], z_axes: int,
                   dtype) -> jax.Array:
    n = math.prod(shape[z_axes:]) if z_axes else math.prod(shape)
    out = flat[..., :n].reshape(shape)
    return out.astype(dtype)
