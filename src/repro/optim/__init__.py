"""Optimizer registry."""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

from . import adamw, grad, lamb, sgd, zero
from ..configs.base import RunConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """Uniform facade: init(params) / update(grads, state, params)."""
    name: str
    cfg: Any

    def init(self, params: PyTree) -> PyTree:
        return _MODS[self.name].init(self.cfg, params)

    def update(self, grads: PyTree, state: PyTree, params: PyTree
               ) -> Tuple[PyTree, PyTree]:
        return _MODS[self.name].update(self.cfg, grads, state, params)


_MODS = {"lamb": lamb, "adamw": adamw, "sgd": sgd}


def make_optimizer(run: RunConfig, pad_multiple: int = 256) -> Optimizer:
    if run.optimizer == "lamb":
        cfg = lamb.LambConfig(learning_rate=run.learning_rate,
                              weight_decay=run.weight_decay, zero1=run.zero1,
                              pad_multiple=pad_multiple,
                              use_fused_kernel=run.fused_optimizer_kernel,
                              master_weights=run.master_weights,
                              state_dtype=run.opt_state_dtype)
    elif run.optimizer == "adamw":
        cfg = adamw.AdamWConfig(learning_rate=run.learning_rate,
                                weight_decay=run.weight_decay, zero1=run.zero1,
                                pad_multiple=pad_multiple)
    elif run.optimizer == "sgd":
        cfg = sgd.SGDConfig(learning_rate=run.learning_rate,
                            weight_decay=run.weight_decay)
    else:
        raise ValueError(run.optimizer)
    return Optimizer(run.optimizer, cfg)


__all__ = ["Optimizer", "make_optimizer", "adamw", "grad", "lamb", "sgd", "zero"]
