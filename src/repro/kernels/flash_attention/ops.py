"""jit'd wrapper for the Pallas flash-attention kernel (TPU target).

``supported()`` gates dispatch: the kernel lowers on TPU backends only; CPU
(tests, dry-run) falls back to the chunked pure-JAX path in models/attention.py,
which is this kernel's oracle at HBM granularity.
"""
from __future__ import annotations

import jax


def supported() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal, q_offset=0, kv_len=None, window=0,
                    block_kv=512, interpret=False):
    """q [B,Sq,Hq,D]; k/v [B,Sk,Hkv,D] (model layout) -> [B,Sq,Hq,D].

    Forward runs the Pallas kernel; gradients flow through the pure-JAX
    custom-VJP chunked path (models.attention), which is this kernel's oracle.
    """
    import jax.numpy as jnp
    from . import kernel
    b = q.shape[0]
    if kv_len is None:
        kv_len = jnp.full((b,), k.shape[1], jnp.int32)
    qT = q.transpose(0, 2, 1, 3)
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    o = kernel.flash_attention_fwd(qT, kT, vT, kv_len, causal=causal,
                                   q_offset=q_offset, window=window,
                                   block_kv=block_kv, interpret=interpret)
    return o.transpose(0, 2, 1, 3)
