"""Pallas TPU flash-attention forward (GQA, causal/window/cache-length masking).

TPU adaptation of the paper's "memory-bound attention B-GEMMs + scale/mask/softmax"
finding (Takeaways 7/9): instead of materializing [Sq, Sk] scores in HBM and
running three separate memory-bound EW kernels over them, each (batch, q-head,
q-block) grid cell streams KV blocks through VMEM, keeping a [block_q, block_kv]
score tile and fp32 (o, m, l) accumulators resident. HBM traffic drops from
O(Sq*Sk) to O(Sq*D + Sk*D) per head.

MXU alignment: block_q/block_kv are multiples of 128; D = head_dim (64/128 for
all assigned archs) is the contraction dim of both tile GEMMs.

Layout: q [B, Hq, Sq, D]; k/v [B, Hkv, Sk, D]. Grid (B*Hq, Sq/block_q); the kv
loop is a fori_loop inside the kernel so the q-tile accumulators never leave
VMEM. Backward runs through the pure-JAX custom-VJP chunked path (same math).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, kvlen_ref, o_ref, *,
                  block_q, block_kv, sk, causal, q_offset, window, scale):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # [block_q, D]
    kv_len = kvlen_ref[0]

    nblocks = sk // block_kv

    def body(j, carry):
        o, m, l = carry
        k = k_ref[0, pl.dslice(j * block_kv, block_kv)].astype(jnp.float32)
        v = v_ref[0, pl.dslice(j * block_kv, block_kv)].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bkv]
        rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
            + qi * block_q + q_offset
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + j * block_kv
        mask = cols < kv_len
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_new = o * alpha[:, None] + jax.lax.dot(p, v)
        return o_new, m_new, l_new

    o0 = jnp.zeros((block_q, q.shape[-1]), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    o, m, l = jax.lax.fori_loop(0, nblocks, body, (o0, m0, l0))
    o_ref[0] = (o / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, kv_len, *, causal, q_offset=0, window=0,
                        block_q=128, block_kv=512, interpret=False):
    """q [B,Hq,Sq,D]; k/v [B,Hkv,Sk,D]; kv_len [B] -> o [B,Hq,Sq,D]."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    block_q = min(block_q, sq)
    block_kv = min(block_kv, sk)
    assert sq % block_q == 0 and sk % block_kv == 0
    scale = 1.0 / (d ** 0.5)

    q4 = q.reshape(b * hq, sq, d)
    # repeat kv per q-head group (views only — blocks are fetched per grid cell)
    k4 = jnp.repeat(k, g, axis=1).reshape(b * hq, sk, d)
    v4 = jnp.repeat(v, g, axis=1).reshape(b * hq, sk, d)
    kvl = jnp.repeat(kv_len, hq).astype(jnp.int32)

    kern = functools.partial(
        _flash_kernel, block_q=block_q, block_kv=block_kv, sk=sk,
        causal=causal, q_offset=q_offset, window=window, scale=scale)
    out = pl.pallas_call(
        kern,
        # jaxlint: allow[pallas-grid-floordiv] sq % block_q asserted above
        grid=(b * hq, sq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda n, i: (n, i, 0)),
            pl.BlockSpec((1, sk, d), lambda n, i: (n, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda n, i: (n, 0, 0)),
            pl.BlockSpec((1,), lambda n, i: (n,)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda n, i: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        interpret=interpret,
    )(q4, k4, v4, kvl)
    return out.reshape(b, hq, sq, d)
