"""Oracle for the Pallas flash-attention kernel: the pure-jnp chunked path."""
from __future__ import annotations

from ...models.attention import chunked_attention, naive_attention

__all__ = ["chunked_attention", "naive_attention"]
