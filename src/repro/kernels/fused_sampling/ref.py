"""Sort-based oracle for the fused sampling epilogue — and the ONE place the
top-k / top-p filtering semantics are defined.

This module replaces the twin ``jnp.sort`` code paths that used to live
inline in ``serving.sampling`` (one full-vocab sort for the top-k threshold,
a second for the nucleus cumsum). It is the parity oracle the fused kernel
is tested against bit-for-bit, and the fallback the serving sampler keeps
available (``sample_tokens(..., fused=False)``).

Canonical filtering semantics (shared with ``ops.py`` / ``kernel.py``)
----------------------------------------------------------------------
Given temperature-scaled logits ``lg`` [S, V] and per-row ``top_k`` /
``top_p``:

1. **top-k** — ``kth`` = the k-th largest *value* of the row; every logit
   ``< kth`` is masked to ``-inf``. Ties at the k-th value are all kept
   (a value threshold, not a rank cut), so the mask is independent of sort
   order among equal logits.
2. **top-p** — on the top-k-masked row, with unnormalized softmax masses
   ``U = exp(lg_k - max)`` and ``Z = sum(U)``, the nucleus threshold is the
   smallest kept value ``v`` whose *strictly-greater mass*
   ``SG(v) = sum(U[lg_k > v])`` stays under ``T = top_p * Z``. Every logit
   ``< v`` is masked to ``-inf``. This keeps exactly the maximal descending-
   probability prefix whose exclusive cumulative mass is below ``top_p``
   (the standard nucleus), again with all ties at the boundary kept.

Why thresholds instead of the usual sort + cumsum + rank cut: the decision
predicate ``SG(v) < T`` is a pure function of a candidate *value*, computed
by one masked reduction — so a sort-free implementation (bisection over the
float bit space, ``ops.py``) and this sort-based one (bisection over ranks
of one descending sort) evaluate the *identical* float expressions and must
agree on every threshold bit-for-bit. With the old cumsum formulation the
two implementations would round the running mass differently and could
disagree by one token exactly at nucleus boundaries.

Both bisections converge because ``SG`` is monotone in ``v`` even in
float32: replacing a 0 with a nonnegative term at a fixed position of a
fixed-shape reduction cannot decrease a round-to-nearest sum.

Degenerate rows are defined (and shared) here too: ``top_k <= 0`` or
``top_k >= V`` disables the rank cut; ``top_p >= 1`` keeps everything
explicitly; an out-of-contract ``top_p <= 0`` clamps to "top-1" via the
``T`` floor; an all-``-inf`` row (``Z == 0``) passes through unmasked.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# floor for the nucleus mass target: keeps the bisection's "the top logit is
# always kept" invariant (SG(max) == 0 < T) even when top_p * Z underflows
# to 0 or an out-of-contract top_p <= 0 slips past SamplingParams
T_FLOOR = 1.1754943508222875e-38        # smallest normal float32

# canonical reduction tile: every float mass in this package (and in
# ``kernels.fused_lm_head``, which re-evaluates these predicates while
# streaming the unembed GEMM over vocab blocks) is summed as partial sums
# over consecutive RED_TILE-lane tiles, folded left-to-right in tile order.
# Fixing the association this way is what lets a streaming implementation
# that never holds the full row reproduce the oracle's floats bit-for-bit:
# any vocab-block width that is a multiple of RED_TILE yields the same
# per-tile partials, and the sequential fold is the same add sequence.
RED_TILE = 128


# ------------------------------------------------ canonical tiled reduction ---
def tile_partial_sums(x: jax.Array) -> jax.Array:
    """Per-tile partial sums [S, ceil(V / RED_TILE)] of ``x`` [S, V]: each
    output element is ``jnp.sum`` over one contiguous RED_TILE-wide tile
    (zero-padded on the right when V is not a tile multiple — exact for the
    mass terms, which are all >= 0 and 0 at masked entries)."""
    s, v = x.shape
    pad = (-v) % RED_TILE
    if pad:
        x = jnp.concatenate([x, jnp.zeros((s, pad), x.dtype)], axis=-1)
    return jnp.sum(x.reshape(s, (v + pad) // RED_TILE, RED_TILE), axis=-1)


def fold_partials(parts: jax.Array) -> jax.Array:
    """Strictly sequential left fold of per-tile partials [S, n] -> [S].
    THE canonical association: ``(((0 + p0) + p1) + ...) + p_{n-1}``. Every
    implementation — oracle, jnp streaming filter, Pallas kernel, the
    LM-head vocab-streaming epilogue, and the tp>1 shard combine (which
    all-gathers per-tile partials and refolds them) — must fold in exactly
    this order to produce the same float."""
    s, n = parts.shape

    def body(i, acc):
        return acc + lax.dynamic_index_in_dim(parts, i, axis=1,
                                              keepdims=False)

    return lax.fori_loop(0, n, body, jnp.zeros((s,), parts.dtype))


def tiled_row_sum(x: jax.Array) -> jax.Array:
    """Canonical row sum [S] of ``x`` [S, V]: RED_TILE partials folded
    sequentially (see :func:`fold_partials`)."""
    return fold_partials(tile_partial_sums(x))


# --------------------------------------------------------------- bit keys ----
def float_to_key(f: jax.Array) -> jax.Array:
    """float32 -> uint32 key, strictly monotone in the float ordering
    (-inf < ... < -0.0 < +0.0 < ... < +inf; NaN patterns land at the ends).
    The fused path bisects this key space instead of sorting."""
    b = lax.bitcast_convert_type(f, jnp.uint32)
    return jnp.where(b >> 31 != 0, ~b, b ^ jnp.uint32(0x80000000))


def key_to_float(k: jax.Array) -> jax.Array:
    """Inverse of :func:`float_to_key`."""
    b = jnp.where(k >> 31 == 0, ~k, k ^ jnp.uint32(0x80000000))
    return lax.bitcast_convert_type(b, jnp.float32)


# ------------------------------------------------- canonical decision math ----
def softmax_mass_stats(lg_k: jax.Array):
    """Unnormalized softmax masses of a (possibly ``-inf``-masked) row:
    ``(U, Z)`` with ``U = exp(lg_k - rowmax)`` (0 at masked entries) and
    ``Z = sum(U)``. Shared verbatim by the oracle and the fused path — the
    nucleus predicate compares these exact floats."""
    m = jnp.max(lg_k, axis=-1)
    safe_m = jnp.where(jnp.isfinite(m), m, 0.0)
    u = jnp.exp(lg_k - safe_m[:, None])
    z = tiled_row_sum(u)
    return u, z


def strict_greater_mass(lg_k: jax.Array, u: jax.Array,
                        v: jax.Array) -> jax.Array:
    """``SG(v)`` [S]: total mass of entries strictly above the candidate
    threshold ``v`` [S]. THE nucleus decision predicate's left-hand side;
    every implementation must call this exact reduction."""
    return tiled_row_sum(jnp.where(lg_k > v[:, None], u, 0.0))


def count_ge_key(keys: jax.Array, mid: jax.Array) -> jax.Array:
    """Entries whose bit key is at or above ``mid`` [S] per row — the
    (integer-exact) top-k decision predicate of the bit bisection. Key-space
    comparison keeps the predicate monotone over the whole uint32 domain
    (NaN bit patterns order below ``-inf`` / above ``+inf`` instead of
    poisoning float compares)."""
    return jnp.sum((keys >= mid[:, None]).astype(jnp.int32), axis=-1)


def mass_above_key(keys_k: jax.Array, u: jax.Array,
                   mid: jax.Array) -> jax.Array:
    """``SG`` evaluated in key space [S]: total mass of entries whose bit
    key is strictly above ``mid``. At the key of any present value this sums
    exactly the same ``u`` terms in the same order as
    :func:`strict_greater_mass` (keys are monotone in floats), so the two
    bisections land on thresholds that mask identically — the only
    candidates where the comparisons differ are ``-0.0``/``+0.0``, and IEEE
    compares make those thresholds equivalent as masks."""
    return tiled_row_sum(jnp.where(keys_k > mid[:, None], u, 0.0))


def nucleus_target(top_p: jax.Array, z: jax.Array) -> jax.Array:
    """The nucleus mass target ``T = top_p * Z``, floored so the row
    maximum is always kept (see ``T_FLOOR``)."""
    return jnp.maximum(top_p.astype(jnp.float32) * z, jnp.float32(T_FLOOR))


# ----------------------------------------------------------- sort-based ref ---
def filter_logits_ref(lg: jax.Array, top_k: jax.Array,
                      top_p: jax.Array) -> jax.Array:
    """Apply top-k then nucleus top-p masking to ``lg`` [S, V] via ONE
    descending sort (the oracle the fused kernel must match bit-for-bit).

    ``top_k`` int32 [S] (``<= 0`` disables), ``top_p`` float32 [S]
    (``>= 1`` disables). Returns ``lg`` with dropped entries at ``-inf``.
    """
    s, v = lg.shape
    lg = lg.astype(jnp.float32)
    desc = jnp.sort(lg, axis=-1)[:, ::-1]

    # top-k: the k-th largest value, selected (not computed) — exact
    k = jnp.where(top_k <= 0, v, jnp.minimum(top_k, v))
    kth = jnp.take_along_axis(desc, (k - 1)[:, None], axis=-1)[:, 0]
    lg_k = jnp.where(lg < kth[:, None], -jnp.inf, lg)
    desc_k = jnp.where(desc < kth[:, None], -jnp.inf, desc)

    # top-p: largest rank whose value still satisfies SG(value) < T,
    # found by bisection over ranks of the (masked) descending sort.
    # pred(desc_k[0]) is always true: SG(rowmax) == 0 < T by the floor.
    u, z = softmax_mass_stats(lg_k)
    t = nucleus_target(top_p, z)

    def body(_, lohi):
        lo, hi = lohi
        mid = lo + ((hi - lo + 1) >> 1)
        cand = jnp.take_along_axis(desc_k, mid[:, None], axis=-1)[:, 0]
        ok = strict_greater_mass(lg_k, u, cand) < t
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)

    lo = jnp.zeros((s,), jnp.int32)
    hi = jnp.full((s,), v - 1, jnp.int32)
    steps = max(1, (v - 1).bit_length())
    lo, _ = lax.fori_loop(0, steps, body, (lo, hi))
    th = jnp.take_along_axis(desc_k, lo[:, None], axis=-1)[:, 0]
    th = jnp.where(top_p >= 1.0, -jnp.inf, th)
    return jnp.where(lg_k < th[:, None], -jnp.inf, lg_k)
