"""Sort-free fused sampling filter: streaming top-k + nucleus top-p.

Replaces the serving sampler's two full-vocab ``jnp.sort`` calls with two
bisections over the monotone uint32 bit-key space of the logits:

* **top-k** — 32 steps of ``count(keys >= mid) >= k``; integer-exact, so it
  recovers the k-th largest value of the row precisely (ties at the k-th
  value all kept, same as the oracle's rank selection).
* **top-p** — 32 steps of ``mass_above_key(mid) < T`` on the top-k-masked
  row. The predicate is the canonical strict-greater-mass test from
  ``ref.py``, so the threshold masks bit-identically to the sort-based
  oracle (see ``ref.py`` for the monotonicity argument).

Each step is one masked reduction over ``[S, V]`` — streaming-friendly,
no ``[S, V]``-sized temporaries beyond the mass vector, no data-dependent
gathers. On TPU (or under ``interpret=True``) the whole filter runs as a
single Pallas kernel (``kernel.py``); elsewhere this module's jnp version
is the production path and is itself ~6x faster than the twin sorts at
smoke-vocab sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import kernel, ref

BISECT_STEPS = 32
# top of the bisection range: excludes key 0xffffffff (a NaN bit pattern that
# never keys a logit) so `hi - lo + 1` cannot wrap uint32 on the first step
TOP_KEY = 0xFFFFFFFE


def supported() -> bool:
    return jax.default_backend() == "tpu"


def filter_logits(lg: jax.Array, top_k: jax.Array, top_p: jax.Array, *,
                  interpret: bool = False) -> jax.Array:
    """Mask ``lg`` [S, V] float32 to its top-k / nucleus-top-p support
    (dropped entries at ``-inf``), bit-identical to
    ``ref.filter_logits_ref``. ``top_k`` int32 [S], ``top_p`` float32 [S].
    """
    if supported() or interpret:
        return kernel.filter_logits(lg, top_k, top_p, interpret=interpret)
    return _filter_logits_jnp(lg, top_k, top_p)


def _filter_logits_jnp(lg: jax.Array, top_k: jax.Array,
                       top_p: jax.Array) -> jax.Array:
    s, v = lg.shape
    lg = lg.astype(jnp.float32)
    keys = ref.float_to_key(lg)

    # --- top-k: largest key with count(keys >= key) >= k ---
    k = jnp.where(top_k <= 0, v, jnp.minimum(top_k, v))

    def kth_body(_, lohi):
        lo, hi = lohi
        mid = lo + ((hi - lo + jnp.uint32(1)) >> 1)
        ok = ref.count_ge_key(keys, mid) >= k
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - jnp.uint32(1))

    lo = jnp.zeros((s,), jnp.uint32)
    hi = jnp.full((s,), TOP_KEY, jnp.uint32)
    lo, _ = lax.fori_loop(0, BISECT_STEPS, kth_body, (lo, hi))
    kth = ref.key_to_float(lo)
    lg_k = jnp.where(lg < kth[:, None], -jnp.inf, lg)

    # --- top-p: smallest key whose strictly-greater mass stays under T ---
    u, z = ref.softmax_mass_stats(lg_k)
    t = ref.nucleus_target(top_p, z)
    keys_k = ref.float_to_key(lg_k)

    def topp_body(_, lohi):
        lo, hi = lohi
        mid = lo + ((hi - lo) >> 1)
        ok = ref.mass_above_key(keys_k, u, mid) < t
        return jnp.where(ok, lo, mid + jnp.uint32(1)), jnp.where(ok, mid, hi)

    lo = jnp.zeros((s,), jnp.uint32)
    hi = jnp.full((s,), TOP_KEY, jnp.uint32)
    _, hi = lax.fori_loop(0, BISECT_STEPS, topp_body, (lo, hi))
    th = ref.key_to_float(hi)
    th = jnp.where(top_p >= 1.0, -jnp.inf, th)
    return jnp.where(lg_k < th[:, None], -jnp.inf, lg_k)
