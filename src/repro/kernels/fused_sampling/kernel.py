"""Pallas TPU kernel: single-pass fused top-k + nucleus top-p logit filter.

One row of temperature-scaled logits stays VMEM-resident for the whole
epilogue: bit-key conversion, the 32-step top-k count bisection, the masked
softmax mass statistics, and the 32-step nucleus mass bisection all run over
the same [1, V] block — one HBM read and one HBM write of the logits instead
of the sort-based sampler's multiple sorted copies. The decision predicates
are the canonical ones from ``ref.py``, evaluated per row (axis -1), so the
kernel masks bit-identically to both the jnp streaming path (``ops.py``) and
the sort-based oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import ref

BISECT_STEPS = 32
TOP_KEY = 0xFFFFFFFE           # see ops.TOP_KEY: keeps uint32 midpoint exact


def _filter_kernel(lg_ref, tk_ref, tp_ref, y_ref, *, vocab):
    lg = lg_ref[...].astype(jnp.float32)                    # [1, V]
    keys = ref.float_to_key(lg)

    # top-k: bisect the largest key with count(keys >= key) >= k
    tk = tk_ref[0, 0]
    k = jnp.where(tk <= 0, vocab, jnp.minimum(tk, vocab))

    def kth_body(_, lohi):
        lo, hi = lohi
        mid = lo + ((hi - lo + jnp.uint32(1)) >> 1)
        cnt = jnp.sum((keys >= mid).astype(jnp.int32), axis=-1)[0]
        ok = cnt >= k
        return (jnp.where(ok, mid, lo),
                jnp.where(ok, hi, mid - jnp.uint32(1)))

    lo, _ = lax.fori_loop(0, BISECT_STEPS, kth_body,
                          (jnp.uint32(0), jnp.uint32(TOP_KEY)))
    kth = ref.key_to_float(lo)
    lg_k = jnp.where(lg < kth, -jnp.inf, lg)

    # top-p: bisect the smallest key whose strictly-greater mass < T
    m = jnp.max(lg_k, axis=-1)[0]
    safe_m = jnp.where(jnp.isfinite(m), m, 0.0)
    u = jnp.exp(lg_k - safe_m)
    # canonical tiled-sequential masses (ref.RED_TILE partials folded left to
    # right) — the same association every other implementation uses
    z = ref.tiled_row_sum(u)[0]
    t = jnp.maximum(tp_ref[0, 0] * z, jnp.float32(ref.T_FLOOR))
    keys_k = ref.float_to_key(lg_k)

    def topp_body(_, lohi):
        lo, hi = lohi
        mid = lo + ((hi - lo) >> 1)
        sg = ref.tiled_row_sum(jnp.where(keys_k > mid, u, 0.0))[0]
        ok = sg < t
        return (jnp.where(ok, lo, mid + jnp.uint32(1)),
                jnp.where(ok, mid, hi))

    _, hi = lax.fori_loop(0, BISECT_STEPS, topp_body,
                          (jnp.uint32(0), jnp.uint32(TOP_KEY)))
    th = ref.key_to_float(hi)
    th = jnp.where(tp_ref[0, 0] >= 1.0, -jnp.inf, th)
    y_ref[...] = jnp.where(lg_k < th, -jnp.inf, lg_k)


def filter_logits(lg: jax.Array, top_k: jax.Array, top_p: jax.Array, *,
                  interpret: bool = False) -> jax.Array:
    """lg: [S, V] float32; top_k: int32 [S]; top_p: float32 [S]."""
    s, v = lg.shape
    return pl.pallas_call(
        functools.partial(_filter_kernel, vocab=v),
        grid=(s,),
        in_specs=[
            pl.BlockSpec((1, v), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, v), jnp.float32),
        interpret=interpret,
    )(lg.astype(jnp.float32), top_k.reshape(s, 1), top_p.reshape(s, 1))
