"""Full-logits oracle for the fused LM-head epilogue — and the ONE place the
token *draw* is defined.

The serving sampler historically drew with ``jax.random.categorical``, whose
Gumbel-noise formulation needs one noise value per vocab entry — a ``[S, V]``
tensor a streaming epilogue cannot afford and a Pallas kernel cannot generate
(threefry does not lower inside Mosaic). This module replaces it with the
classic **inverse-CDF draw**: one uniform per row, drawn OUTSIDE the kernel
from the determinism contract's ``fold_in(key(seed), position)`` key, then a
prefix-sum walk over the (filtered, temperature-scaled) softmax masses. The
draw is statistically exact categorical sampling and is defined entirely in
terms of the canonical tiled-sequential reductions of
``kernels.fused_sampling.ref`` — so a vocab-streaming implementation that
only ever holds one ``[S, tile]`` block reproduces it bit-for-bit.

Canonical draw (shared by every implementation)
-----------------------------------------------
Given final filtered scaled logits ``lg_f`` [S, V] and per-row uniforms
``rs`` in [0, 1):

1. ``m = max(lg_f)`` per row; ``safe_m = m`` where finite else 0.
2. ``u = exp(lg_f - safe_m)`` (0 at masked entries).
3. ``Z = fold_partials(tile_partial_sums(u))`` — the canonical
   tiled-sequential row sum.
4. ``target = rs * Z``.
5. The token is the FIRST index ``j`` (global index order) whose inclusive
   prefix mass exceeds ``target``, where the prefix at lane ``l`` of tile
   ``t`` is ``acc_t + cumsum(u_tile)[l]`` — ``acc_t`` the sequential fold of
   the *partials* of tiles ``0..t-1`` (the same adds as step 3) and the
   cumsum evaluated on an ``[S, RED_TILE]`` block in every implementation.
6. If no lane ever exceeds ``target`` the token is 0. That covers both the
   degenerate all-``-inf`` row (``Z == 0``, ``u == 0`` everywhere) and the
   measure-zero rounding edge where ``rs * Z`` lands at or above the final
   prefix — deterministically, on every implementation.

``head_epilogue`` then composes the whole fused-decode epilogue —
greedy argmax on the raw logits, the finite-ness probe, temperature scaling,
the ``fused_sampling`` top-k/top-p filter, this draw — as the oracle the
streaming ``ops.py`` path and the Pallas kernel are tested against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..fused_sampling import ref as sref

RED_TILE = sref.RED_TILE


def gemm_tile(v: int) -> int:
    """The vocab-block width the streaming implementations sweep with: the
    widest of (512, 384, 256, 128) dividing ``v`` — every candidate is a
    RED_TILE multiple, so the canonical reduction tiles nest inside GEMM
    tiles exactly. A ``v`` none divides (possible only for unit-test vocabs;
    the engine always serves ``pad_vocab`` multiples of 128) degrades to one
    full-width block, with the reductions zero-padding internally."""
    for t in (512, 384, 256, 128):
        if v % t == 0:
            return t
    return v


def row_uniforms(seeds: jax.Array, positions: jax.Array) -> jax.Array:
    """The per-row draw uniforms [S] float32 in [0, 1): one
    ``jax.random.uniform`` from the determinism contract's
    ``fold_in(key(seed), position)`` key. Defined here once so the unfused
    sampler, the streaming epilogue, and the engine's fused decode step all
    derive the identical ``rs`` for the same (seed, position)."""
    def one(s, p):
        key = jax.random.fold_in(jax.random.key(s), p)
        return jax.random.uniform(key, (), jnp.float32)
    return jax.vmap(one)(seeds.astype(jnp.uint32),
                         positions.astype(jnp.int32))


def pad_tiles(u: jax.Array) -> jax.Array:
    """``u`` [S, V] -> [S, n, RED_TILE] with zero right-padding — the tile
    view both the fold partials and the draw's per-tile cumsum walk use.
    Zero pads are exact for the mass terms and can never be drawn (their
    inclusive prefix equals the preceding real lane's)."""
    s, v = u.shape
    pad = (-v) % RED_TILE
    if pad:
        u = jnp.concatenate([u, jnp.zeros((s, pad), u.dtype)], axis=-1)
    return u.reshape(s, (v + pad) // RED_TILE, RED_TILE)


def draw_tokens(lg_f: jax.Array, rs: jax.Array) -> jax.Array:
    """Canonical inverse-CDF draw: filtered scaled logits ``lg_f`` [S, V] +
    uniforms ``rs`` [S] -> int32 tokens [S]. See the module docstring for
    the exact (bit-reproducible) definition."""
    s, v = lg_f.shape
    m = jnp.max(lg_f, axis=-1)
    safe_m = jnp.where(jnp.isfinite(m), m, 0.0)
    u = pad_tiles(jnp.exp(lg_f.astype(jnp.float32) - safe_m[:, None]))
    parts = jnp.sum(u, axis=-1)                          # [S, n] tile masses
    target = rs.astype(jnp.float32) * sref.fold_partials(parts)

    def body(i, carry):
        acc, tok = carry
        tile = lax.dynamic_index_in_dim(u, i, axis=1, keepdims=False)
        cs = acc[:, None] + jnp.cumsum(tile, axis=-1)    # [S, RED_TILE]
        hit = cs > target[:, None]
        idx = (jnp.argmax(hit, axis=-1).astype(jnp.int32)
               + i.astype(jnp.int32) * RED_TILE)
        tok = jnp.where((tok < 0) & jnp.any(hit, axis=-1), idx, tok)
        part = lax.dynamic_index_in_dim(parts, i, axis=1, keepdims=False)
        return acc + part, tok

    acc0 = jnp.zeros((s,), jnp.float32)
    tok0 = jnp.full((s,), -1, jnp.int32)
    _, tok = lax.fori_loop(0, u.shape[1], body, (acc0, tok0))
    return jnp.where(tok < 0, 0, tok)


def head_epilogue(logits, rs, temps, top_k, top_p, *, sampled: bool,
                  filtered: bool, filter_fn=None):
    """Whole fused-decode epilogue on MATERIALIZED logits [S, V] — the
    oracle. Returns ``(tokens int32 [S], ok bool [S])`` where ``ok`` is the
    per-row all-finite probe the engine's sanitizer consumes.

    ``sampled``/``filtered`` are static flags matching the engine's jit
    variants; ``filter_fn`` defaults to the sort-based
    ``fused_sampling.ref.filter_logits_ref`` oracle (any of the package's
    bit-identical filter implementations is equivalent)."""
    ok = jnp.all(jnp.isfinite(logits), axis=-1)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if not sampled:
        return greedy, ok
    temps = temps.astype(jnp.float32)
    safe_t = jnp.where(temps > 0, temps, 1.0)
    lg = logits.astype(jnp.float32) / safe_t[:, None]
    if filtered:
        fn = filter_fn if filter_fn is not None else sref.filter_logits_ref
        lg = fn(lg, top_k.astype(jnp.int32), top_p.astype(jnp.float32))
    drawn = draw_tokens(lg, rs)
    return jnp.where(temps > 0, drawn, greedy), ok
