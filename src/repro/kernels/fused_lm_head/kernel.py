"""Pallas TPU kernel: fused unembed GEMM + sampling epilogue, logits
VMEM-resident.

One grid pass over vocab tiles: each step multiplies the (revisited) hidden
block [S, D] by its [D, tile] slice of the head weight and writes the f32
logits tile into a ``[S, V]`` VMEM scratch that persists across the
sequential grid. The LAST step runs the whole epilogue — greedy argmax,
finite probe, temperature scaling, the sort-free top-k/top-p bisections, and
the canonical inverse-CDF draw — on the on-chip logits via the exact
``ref.head_epilogue`` code path, then emits only the ``int32 [S]`` tokens
and the ``[S]`` probe. HBM sees one read of the head weight and never a
logits row.

VMEM ceiling: the scratch is ``4 * S * V`` bytes — at the serving shapes
(S = decode slots <= 8, V padded to 128) that is ~8 MB even for a 256k
vocab, inside the ~16 MB VMEM budget. Larger S*V would need the carried-
statistics multi-sweep structure of ``ops.py`` instead; the dispatcher can
only pick this kernel on TPU, where that budget holds for every servable
config.

The per-row draw uniforms arrive as an input (``[S]``, computed outside
from the determinism contract's ``fold_in(key(seed), position)`` key):
threefry does not lower inside Mosaic, and the inverse-CDF draw is defined
so one scalar per row is all the randomness the epilogue needs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..fused_sampling import ops as sops
from . import ref


def _head_kernel(x_ref, w_ref, rs_ref, temps_ref, tk_ref, tp_ref,
                 tok_ref, ok_ref, lg_ref, *, n_tiles, tile, sampled,
                 filtered, softcap):
    t = pl.program_id(0)
    # jaxlint: allow[pallas-accum-dtype] deliberately mirrors unembed's
    # model-dtype matmul (MXU f32 accumulate, round to model dtype, THEN
    # upcast) — fp32-preferred output would skip the rounding the reference
    # logits have and break the bit-parity contract
    lt = (x_ref[...] @ w_ref[...].astype(x_ref.dtype)).astype(jnp.float32)
    if softcap:
        lt = softcap * jnp.tanh(lt / softcap)
    lg_ref[:, pl.dslice(t * tile, tile)] = lt

    @pl.when(t == n_tiles - 1)
    def _epilogue():
        # the full-logits oracle, evaluated on the VMEM-resident row with
        # the sort-free bisection filter (no jnp.sort inside the kernel)
        tokens, ok = ref.head_epilogue(
            lg_ref[...], rs_ref[:, 0], temps_ref[:, 0], tk_ref[:, 0],
            tp_ref[:, 0], sampled=sampled, filtered=filtered,
            filter_fn=sops._filter_logits_jnp)
        tok_ref[:, 0] = tokens
        ok_ref[:, 0] = ok.astype(jnp.int32)


def head_tokens(x: jax.Array, w: jax.Array, rs: jax.Array, temps: jax.Array,
                top_k: jax.Array, top_p: jax.Array, *, sampled: bool,
                filtered: bool, softcap=None, interpret: bool = False):
    """``x`` [S, D] (model dtype), ``w`` [D, V] head weight -> ``(tokens
    int32 [S], ok bool [S])``, bit-identical to ``ref.head_epilogue`` on the
    materialized logits."""
    s, d = x.shape
    v = w.shape[1]
    tile = ref.gemm_tile(v)
    n_tiles = v // tile
    tok, ok = pl.pallas_call(
        functools.partial(_head_kernel, n_tiles=n_tiles, tile=tile,
                          sampled=sampled, filtered=filtered,
                          softcap=softcap),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((s, d), lambda t: (0, 0)),
            pl.BlockSpec((d, tile), lambda t: (0, t)),
            pl.BlockSpec((s, 1), lambda t: (0, 0)),
            pl.BlockSpec((s, 1), lambda t: (0, 0)),
            pl.BlockSpec((s, 1), lambda t: (0, 0)),
            pl.BlockSpec((s, 1), lambda t: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((s, 1), lambda t: (0, 0)),
                   pl.BlockSpec((s, 1), lambda t: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((s, 1), jnp.int32),
                   jax.ShapeDtypeStruct((s, 1), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((s, v), jnp.float32)],
        interpret=interpret,
    )(x, w, rs.astype(jnp.float32).reshape(s, 1),
      temps.astype(jnp.float32).reshape(s, 1),
      top_k.astype(jnp.int32).reshape(s, 1),
      top_p.astype(jnp.float32).reshape(s, 1))
    return tok[:, 0], ok[:, 0].astype(bool)
