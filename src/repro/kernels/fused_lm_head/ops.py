"""Vocab-streaming LM-head epilogue: hidden state [S, D] -> sampled int32
token [S] without ever materializing the ``[S, V]`` logits.

The unembed GEMM is tiled over vocab blocks (``ref.gemm_tile``); every
statistic the epilogue needs — the greedy argmax, the sanitizer's all-finite
probe, the top-k/top-p bisection predicates of ``kernels.fused_sampling``,
the softmax masses, and the inverse-CDF draw's prefix walk — is carried
across tiles in ``[S]``- or ``[S, V / RED_TILE]``-sized accumulators. Logit
tiles are *recomputed* per bisection sweep rather than stored: the whole
point is that HBM never holds a row of logits, and on the accelerator the
weight tile reads are the traffic the paper says we already pay once.

Bit-identity with the full-logits oracle (``ref.head_epilogue``) is by
construction, not tolerance:

* tiled GEMM == full GEMM under jit (the convert folds into the dot either
  way, so per-element logits match bitwise);
* integer predicates (top-k counts, argmax/first-hit index compares) are
  order-exact;
* every float mass is summed as the canonical RED_TILE partials folded
  left-to-right (``fused_sampling.ref``), and the draw's within-tile cumsum
  runs on an ``[S, RED_TILE]`` block in both implementations.

Tensor-parallel (``axis_name`` set): each shard slices its own contiguous
vocab columns from the REPLICATED head weight (the sharding layer keeps
embedding/head/norms replicated — see ``parallel/sharding.py``), sweeps its
slice, and the shards combine carried statistics, never logits: integer
psums for the top-k counts, an all-gather of (max, argmax-candidate, probe)
triples, and all-gathers of the per-RED_TILE-tile mass partials ``[S,
V / tp / RED_TILE]`` which every shard refolds in canonical global tile
order. (A psum of per-shard folded totals would NOT be bit-exact — float
folds do not reassociate — which is why partials cross the wire instead.)
Requires ``(V / tp) % RED_TILE == 0`` so shard boundaries land on canonical
tile boundaries; the engine checks :func:`tp_fusable` and serves the
unfused path otherwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..fused_sampling import ops as sops
from ..fused_sampling import ref as sref
from . import kernel, ref

RED_TILE = sref.RED_TILE
BISECT_STEPS = sops.BISECT_STEPS
TOP_KEY = sops.TOP_KEY
_INT_MAX = jnp.int32(2 ** 31 - 1)


def supported() -> bool:
    return jax.default_backend() == "tpu"


def tp_fusable(vocab: int, tp: int) -> bool:
    """Whether the fused head can serve this (padded) vocab at this tp:
    shard slices must be whole numbers of canonical reduction tiles."""
    return tp <= 1 or (vocab % tp == 0 and (vocab // tp) % RED_TILE == 0)


def head_tokens(x: jax.Array, w: jax.Array, rs: jax.Array, temps: jax.Array,
                top_k: jax.Array, top_p: jax.Array, *, sampled: bool,
                filtered: bool, softcap=None, axis_name=None, tp: int = 1,
                interpret: bool = False):
    """Fused unembed + sample: ``x`` [S, D] hidden, ``w`` [D, V] head weight
    (model dtype, REPLICATED under tp) -> ``(tokens int32 [S], ok bool [S])``
    with ``ok`` the per-row all-finite probe of the raw logits.

    ``rs`` float32 [S] are the draw uniforms (``ref.row_uniforms``); rows
    with ``temps == 0`` take the raw-logits argmax. ``sampled`` / ``filtered``
    are the engine's static jit-variant flags. Dispatches to the Pallas
    kernel on TPU (or under ``interpret``) for the single-shard case; the
    jnp streaming path is the production path elsewhere and under tp > 1.
    """
    if axis_name is None and (supported() or interpret):
        return kernel.head_tokens(x, w, rs, temps, top_k, top_p,
                                  sampled=sampled, filtered=filtered,
                                  softcap=softcap, interpret=interpret)
    return _head_tokens_jnp(x, w, rs, temps, top_k, top_p, sampled=sampled,
                            filtered=filtered, softcap=softcap,
                            axis_name=axis_name, tp=tp)


def _head_tokens_jnp(x, w, rs, temps, top_k, top_p, *, sampled, filtered,
                     softcap, axis_name, tp):
    s, _ = x.shape
    v_total = w.shape[1]
    shard_tp = axis_name is not None and tp > 1
    if shard_tp:
        assert tp_fusable(v_total, tp), (v_total, tp)
        v_local = v_total // tp
        shard = lax.axis_index(axis_name)
        w = lax.dynamic_slice_in_dim(w, shard * v_local, v_local, axis=1)
        offset = (shard * v_local).astype(jnp.int32)
    else:
        v_local = v_total
        offset = jnp.int32(0)
    t_w = ref.gemm_tile(v_local)
    n_tiles = v_local // t_w
    n128 = -(-v_local // RED_TILE)          # local canonical tiles
    wx = w.astype(x.dtype)

    def logits_tile(t):
        wt = lax.dynamic_slice_in_dim(wx, t * t_w, t_w, axis=1)
        lt = (x @ wt).astype(jnp.float32)
        if softcap:
            lt = softcap * jnp.tanh(lt / softcap)
        return lt

    # ---- sweep 1: raw-logits running max, first-occurrence argmax, probe --
    def max_body(t, carry):
        m, am, ok = carry
        lt = logits_tile(t)
        tm = jnp.max(lt, axis=-1)
        ta = jnp.argmax(lt, axis=-1).astype(jnp.int32) + t * t_w + offset
        return (jnp.maximum(m, tm), jnp.where(tm > m, ta, am),
                ok & jnp.all(jnp.isfinite(lt), axis=-1))

    m_raw, am, ok = lax.fori_loop(
        0, n_tiles, max_body,
        (jnp.full((s,), -jnp.inf, jnp.float32),
         jnp.full((s,), offset, jnp.int32), jnp.ones((s,), bool)))

    if shard_tp:
        vals = lax.all_gather(m_raw, axis_name)            # [tp, S]
        idxs = lax.all_gather(am, axis_name)
        m_raw = jnp.max(vals, axis=0)                      # max is exact
        # first global occurrence = min index among shards hitting the max
        am = jnp.min(jnp.where(vals == m_raw[None, :], idxs, _INT_MAX),
                     axis=0)
        ok = jnp.all(lax.all_gather(ok, axis_name), axis=0)
    if not sampled:
        return am, ok

    # ---- scaled domain (division by a positive is monotone, so the scaled
    # row max is exactly the raw max divided — no extra sweep) ----
    temps = temps.astype(jnp.float32)
    safe_t = jnp.where(temps > 0, temps, 1.0)
    m_scaled = m_raw / safe_t
    safe_m = jnp.where(jnp.isfinite(m_scaled), m_scaled, 0.0)

    def scaled_tile(t):
        return logits_tile(t) / safe_t[:, None]

    def count_ge(mid):
        def body(t, c):
            keys = sref.float_to_key(scaled_tile(t))
            return c + jnp.sum((keys >= mid[:, None]).astype(jnp.int32),
                               axis=-1)
        cnt = lax.fori_loop(0, n_tiles, body, jnp.zeros((s,), jnp.int32))
        return lax.psum(cnt, axis_name) if shard_tp else cnt

    def mass_parts(tile_fn, mid=None):
        """Local per-RED_TILE-tile partial masses [S, n128] of
        ``exp(tile - safe_m)``, optionally masked to keys > mid."""
        def body(t, parts):
            lt = tile_fn(t)
            ut = jnp.exp(lt - safe_m[:, None])
            if mid is not None:
                ut = jnp.where(sref.float_to_key(lt) > mid[:, None], ut, 0.0)
            sub = sref.tile_partial_sums(ut)
            return lax.dynamic_update_slice_in_dim(
                parts, sub, t * sub.shape[1], axis=1)
        return lax.fori_loop(0, n_tiles, body,
                             jnp.zeros((s, n128), jnp.float32))

    def fold_global(parts_local):
        """Canonical global fold of local partials; under tp the shards
        gather each other's partials and every shard refolds the full
        sequence in global tile order — bit-exact at any tp."""
        if shard_tp:
            g = lax.all_gather(parts_local, axis_name)     # [tp, S, n128]
            parts = jnp.transpose(g, (1, 0, 2)).reshape(s, -1)
        else:
            parts = parts_local
        return parts, sref.fold_partials(parts)

    # ---- top-k: the same 32-step bit-key count bisection as the filter ----
    if filtered:
        k = jnp.where(top_k <= 0, v_total, jnp.minimum(top_k, v_total))

        def kth_step(_, lohi):
            lo, hi = lohi
            mid = lo + ((hi - lo + jnp.uint32(1)) >> 1)
            take = count_ge(mid) >= k
            return (jnp.where(take, mid, lo),
                    jnp.where(take, hi, mid - jnp.uint32(1)))

        lo, _ = lax.fori_loop(0, BISECT_STEPS, kth_step,
                              (jnp.zeros((s,), jnp.uint32),
                               jnp.full((s,), TOP_KEY, jnp.uint32)))
        kth = sref.key_to_float(lo)

        def masked_tile(t):
            lt = scaled_tile(t)
            return jnp.where(lt < kth[:, None], -jnp.inf, lt)
    else:
        masked_tile = scaled_tile

    # ---- top-p: the same 32-step mass bisection, masses refolded from
    # carried partials each step ----
    if filtered:
        _, z = fold_global(mass_parts(masked_tile))
        t_nuc = sref.nucleus_target(top_p, z)

        def topp_step(_, lohi):
            lo, hi = lohi
            mid = lo + ((hi - lo) >> 1)
            _, sg = fold_global(mass_parts(masked_tile, mid))
            take = sg < t_nuc
            return (jnp.where(take, lo, mid + jnp.uint32(1)),
                    jnp.where(take, mid, hi))

        _, hi = lax.fori_loop(0, BISECT_STEPS, topp_step,
                              (jnp.zeros((s,), jnp.uint32),
                               jnp.full((s,), TOP_KEY, jnp.uint32)))
        th = sref.key_to_float(hi)
        th = jnp.where(top_p >= 1.0, -jnp.inf, th)

        def final_tile(t):
            lt = masked_tile(t)
            return jnp.where(lt < th[:, None], -jnp.inf, lt)
    else:
        final_tile = masked_tile

    # ---- inverse-CDF draw: Z from carried partials, then the prefix walk
    # (ref.draw_tokens step 5, with the entering accs precomputed by the
    # identical sequential adds so the tp shards can walk their slices) ----
    parts_g, zprime = fold_global(mass_parts(final_tile))
    target = rs.astype(jnp.float32) * zprime
    n_global = parts_g.shape[1]

    def acc_body(i, accs):
        prev = lax.dynamic_index_in_dim(accs, i, axis=1, keepdims=False)
        part = lax.dynamic_index_in_dim(parts_g, i, axis=1, keepdims=False)
        return lax.dynamic_update_slice_in_dim(
            accs, (prev + part)[:, None], i + 1, axis=1)

    accs = lax.fori_loop(0, n_global - 1, acc_body,
                         jnp.zeros((s, n_global), jnp.float32))
    local_base = (offset // RED_TILE).astype(jnp.int32)

    def hit_body(t, tok):
        u3 = ref.pad_tiles(jnp.exp(final_tile(t) - safe_m[:, None]))
        t128 = u3.shape[1]

        def sub_body(j, tok):
            g = t * t128 + j + local_base                # global 128-tile
            acc = lax.dynamic_index_in_dim(accs, g, axis=1, keepdims=False)
            tile = lax.dynamic_index_in_dim(u3, j, axis=1, keepdims=False)
            cs = acc[:, None] + jnp.cumsum(tile, axis=-1)
            hit = cs > target[:, None]
            idx = (jnp.argmax(hit, axis=-1).astype(jnp.int32)
                   + g.astype(jnp.int32) * RED_TILE)
            return jnp.where((tok < 0) & jnp.any(hit, axis=-1), idx, tok)

        return lax.fori_loop(0, t128, sub_body, tok)

    tok = lax.fori_loop(0, n_tiles, hit_body, jnp.full((s,), -1, jnp.int32))
    if shard_tp:
        g = lax.all_gather(tok, axis_name)
        tok = jnp.min(jnp.where(g < 0, _INT_MAX, g), axis=0)
        tok = jnp.where(tok == _INT_MAX, -1, tok)
    drawn = jnp.where(tok < 0, 0, tok)
    return jnp.where(temps > 0, drawn, am).astype(jnp.int32), ok
