"""jit'd wrapper for fused scale+mask+softmax; ref fallback off-TPU."""
from __future__ import annotations

import jax

from . import kernel, ref


def supported() -> bool:
    return jax.default_backend() == "tpu"


def scale_mask_softmax(s, *, scale: float, causal: bool, q_offset: int = 0,
                       interpret: bool = False):
    if not (supported() or interpret):
        return ref.scale_mask_softmax(s, scale=scale, causal=causal,
                                      q_offset=q_offset)
    shape = s.shape
    s3 = s.reshape(-1, shape[-2], shape[-1])
    y = kernel.scale_mask_softmax(s3, scale=scale, causal=causal,
                                  q_offset=q_offset, interpret=interpret)
    return y.reshape(shape)
