"""Pallas TPU kernel: fused scale + causal-mask + softmax over score rows.

The paper's "Scale, Mask, Soft." ops are separate memory-bound kernels on the
profiled GPU (Fig 8); fused here into one VMEM-resident pass per row tile:
one read + one write of the [Sq, Sk] scores instead of ~6.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
TILE_Q = 128


def _softmax_kernel(s_ref, y_ref, *, scale, causal, q_offset, tile_q):
    i = pl.program_id(1)
    x = s_ref[...].astype(jnp.float32) * scale
    if causal:
        sq, sk = x.shape[-2], x.shape[-1]
        rows = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 0) \
            + i * tile_q + q_offset
        cols = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 1)
        x = jnp.where(cols <= rows, x, NEG_INF)
    m = jnp.max(x, axis=-1, keepdims=True)
    p = jnp.exp(x - m)
    y_ref[...] = (p / jnp.sum(p, axis=-1, keepdims=True)).astype(y_ref.dtype)


def scale_mask_softmax(s, *, scale: float, causal: bool, q_offset: int = 0,
                       interpret: bool = False):
    """s: [N, Sq, Sk] (N = batch*heads)."""
    n, sq, sk = s.shape
    tile = min(TILE_Q, sq)
    assert sq % tile == 0
    spec = pl.BlockSpec((1, tile, sk), lambda b, i: (b, i, 0))
    return pl.pallas_call(
        functools.partial(_softmax_kernel, scale=scale, causal=causal,
                          q_offset=q_offset, tile_q=tile),
        # jaxlint: allow[pallas-grid-floordiv] sq % tile asserted above
        grid=(n, sq // tile),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n, sq, sk), s.dtype),
        interpret=interpret,
    )(s)
