"""Oracle for fused scale+mask+softmax (the paper's attention-head EW phase)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def scale_mask_softmax(s, *, scale: float, causal: bool, q_offset: int = 0):
    """s: [..., Sq, Sk] raw scores -> softmax(scale*s + causal mask), fp32 stats."""
    x = s.astype(jnp.float32) * scale
    if causal:
        sq, sk = s.shape[-2], s.shape[-1]
        rows = jnp.arange(sq)[:, None] + q_offset
        cols = jnp.arange(sk)[None, :]
        x = jnp.where(cols <= rows, x, NEG_INF)
    m = jnp.max(x, axis=-1, keepdims=True)
    p = jnp.exp(x - m)
    return (p / jnp.sum(p, axis=-1, keepdims=True)).astype(s.dtype)
