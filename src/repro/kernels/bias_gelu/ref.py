"""Oracle for the fused bias+GeLU kernel (paper §3.2.3 GeLU phase)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bias_gelu(x, bias=None):
    h = x if bias is None else x + bias.astype(x.dtype)
    return jax.nn.gelu(h, approximate=True)
