"""Pallas TPU kernel: fused bias-add + GeLU (tanh approximation, as in BERT).

The paper (§3.2.3) measures GeLU as memory-latency *and* bandwidth bound with
~1 op/byte; fusing the bias-add halves its HBM passes. Elementwise 2-D tiling.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 256
_SQRT_2_OVER_PI = math.sqrt(2.0 / math.pi)


def _bias_gelu_kernel(x_ref, b_ref, y_ref):
    x = x_ref[...].astype(jnp.float32)
    if b_ref is not None:
        x = x + b_ref[...].astype(jnp.float32)
    inner = _SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)
    y = 0.5 * x * (1.0 + jnp.tanh(inner))
    y_ref[...] = y.astype(y_ref.dtype)


def bias_gelu(x, bias=None, *, interpret: bool = False):
    """x: [R, F]; bias: [F] or None."""
    r, f = x.shape
    tile = min(TILE_R, r)
    assert r % tile == 0, (r, tile)
    row = pl.BlockSpec((tile, f), lambda i: (i, 0))
    if bias is not None:
        vec = pl.BlockSpec((f,), lambda i: (0,))
        return pl.pallas_call(
            # jaxlint: allow[pallas-grid-floordiv] r % tile asserted above
            _bias_gelu_kernel, grid=(r // tile,),
            in_specs=[row, vec], out_specs=row,
            out_shape=jax.ShapeDtypeStruct((r, f), x.dtype),
            interpret=interpret)(x, bias)
    return pl.pallas_call(
        # jaxlint: allow[pallas-grid-floordiv] r % tile asserted above
        lambda xr, yr: _bias_gelu_kernel(xr, None, yr), grid=(r // tile,),
        in_specs=[row], out_specs=row,
        out_shape=jax.ShapeDtypeStruct((r, f), x.dtype),
        interpret=interpret)(x)
