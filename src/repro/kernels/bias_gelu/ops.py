"""jit'd wrapper for fused bias+GeLU; ref fallback off-TPU."""
from __future__ import annotations

import jax

from . import kernel, ref


def supported() -> bool:
    return jax.default_backend() == "tpu"


def bias_gelu(x, bias=None, *, interpret: bool = False):
    if not (supported() or interpret):
        return ref.bias_gelu(x, bias)
    shape = x.shape
    y = kernel.bias_gelu(x.reshape(-1, shape[-1]), bias, interpret=interpret)
    return y.reshape(shape)
