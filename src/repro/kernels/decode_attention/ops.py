"""Dispatch wrapper for paged decode-attention.

The Pallas kernel lowers on TPU backends (and everywhere under
``interpret=True``, which is how the parity tests run it); CPU serving and the
dry-run fall back to the pure-JAX gather in ``ref.py`` — identical numerics to
the static engine's dense decode path.

Head counts are whatever the caller's arrays carry, NOT an arch contract:
under the serving engine's tensor parallelism these wrappers run inside
shard_map, where ``Hq``/``Hkv`` are the *local* head counts (arch counts
divided by tp) and the page pools are the shard's heads' slice of every
physical page. The only invariant is GQA consistency, Hq % Hkv == 0 — which
head sharding preserves because tp divides both counts.
"""
from __future__ import annotations

import jax


def supported() -> bool:
    return jax.default_backend() == "tpu"


def paged_decode_attention(q, k_pages, v_pages, page_table, seq_lens, *,
                           interpret=False):
    """q [B, Hq, D]; k/v_pages [P, page, Hkv, D]; page_table [B, max_pages];
    seq_lens [B] -> [B, Hq, D]."""
    if supported() or interpret:
        from . import kernel
        return kernel.paged_decode_attention_fwd(
            q, k_pages, v_pages, page_table, seq_lens, interpret=interpret)
    from . import ref
    return ref.paged_decode_attention(q, k_pages, v_pages, page_table,
                                      seq_lens)


def paged_prefill_attention(q, k_pages, v_pages, page_row, start, total_len,
                            *, interpret=False):
    """Chunked-prefill attention for one sequence (see kernel/ref docstrings).
    q [C, Hq, D]; page_row [max_pages]; total_len = start + valid chunk
    tokens -> [C, Hq, D]."""
    if supported() or interpret:
        from . import kernel
        return kernel.paged_prefill_attention_fwd(
            q, k_pages, v_pages, page_row, start, total_len,
            interpret=interpret)
    from . import ref
    return ref.paged_prefill_attention(q, k_pages, v_pages, page_row, start,
                                       total_len)
