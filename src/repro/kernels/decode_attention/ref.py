"""Oracle for the Pallas paged decode-attention kernel.

Gathers K/V pages through the page table into the dense [B, L, Hkv, D] layout
and delegates to ``naive_attention`` — the exact numerics of the static
engine's decode path, so engine-parity tests compare like with like.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...models.attention import naive_attention


def paged_prefill_attention(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, page_row: jax.Array,
                            start, total_len) -> jax.Array:
    """Chunked-prefill attention for one sequence against its paged cache.

    q [C, Hq, D] — queries of one prompt chunk, row i at position start + i
    (the chunk's own K/V must already be written into the pages);
    page_row [max_pages]; total_len = start + valid tokens in the chunk.
    -> [C, Hq, D]. Padding rows (position >= total_len) return garbage the
    engine never reads; tokens attend causally to the cached prefix plus the
    chunk itself.
    """
    c, hq, d = q.shape
    _, page_size, hkv, _ = k_pages.shape
    k = k_pages[page_row].reshape(1, -1, hkv, d)
    v = v_pages[page_row].reshape(1, -1, hkv, d)
    kv_len = jnp.asarray(total_len, jnp.int32).reshape(1)
    o = naive_attention(q[None], k, v, causal=True,
                        q_offset=jnp.asarray(start, jnp.int32), kv_len=kv_len)
    return o[0]


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           seq_lens: jax.Array) -> jax.Array:
    """Single-query attention against a paged KV cache.

    q [B, Hq, D]; k_pages/v_pages [P, page_size, Hkv, D];
    page_table [B, max_pages] (physical page ids, 0 = null page);
    seq_lens [B] = valid cache length per sequence (0 = inactive slot).
    -> [B, Hq, D]
    """
    b, hq, d = q.shape
    _, page_size, hkv, _ = k_pages.shape
    k = k_pages[page_table].reshape(b, -1, hkv, d)
    v = v_pages[page_table].reshape(b, -1, hkv, d)
    o = naive_attention(q[:, None], k, v, causal=False,
                        kv_len=seq_lens.astype(jnp.int32))
    return o[:, 0]
