"""Pallas TPU paged decode-attention (single query per sequence, GQA).

The serving regime the paper's §3.2.3 measurements predict to be memory-bound:
at decode the attention "B-GEMMs" degenerate to matrix-vector products, so
runtime is the HBM read of the KV cache itself. With a *paged* cache the K/V
rows of one sequence are scattered across fixed-size pages of a global pool;
this kernel gathers them page-by-page through a scalar-prefetched page table,
so the gather happens in the BlockSpec index_map (pipelined HBM->VMEM DMAs)
instead of a materialized [B, L, H, D] gather in HBM.

Layout: q [B, Hkv, G, D] (G = Hq/Hkv query heads per KV head); k/v pools
[P, page_size, Hkv, D]; page_table [B, max_pages]; seq_lens [B]. Grid
(B, Hkv, max_pages): the page loop is the innermost grid dim, carrying fp32
online-softmax accumulators (acc, m, l) in VMEM scratch. Pages at or past
seq_len are skipped with ``pl.when`` (their table entries point at the null
page 0), so per-step work tracks the sequence's *actual* length, not max_len.

``_paged_prefill_kernel`` is the multi-query sibling used by chunked prefill:
one prompt chunk of C tokens (single sequence, grid (Hkv, max_pages)) attends
causally to the cached prefix plus itself through the same scalar-prefetched
page walk, with [C*G, D] accumulators — so prompt ingestion streams page-sized
K/V tiles exactly like decode instead of materializing a dense cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_decode_kernel(pt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, page_size, scale):
    b = pl.program_id(0)
    j = pl.program_id(2)
    sl = sl_ref[b]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip pages wholly past the end of the sequence (covers inactive slots,
    # sl == 0, whose rows stay zero after the final normalization)
    @pl.when(j * page_size < sl)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # [page, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, page]
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + j * page_size
        s = jnp.where(cols < sl, s, NEG_INF)
        m_prev = m_ref[...]                                   # [G, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_prefill_kernel(pt_ref, meta_ref, q_ref, k_ref, v_ref, o_ref,
                          acc_ref, m_ref, l_ref, *, page_size, g, scale):
    j = pl.program_id(1)
    start = meta_ref[0]                 # tokens already cached (chunk offset)
    total = meta_ref[1]                 # valid cache length after this chunk

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # a page contributes iff some valid token can see it: causality caps the
    # visible cache at the chunk's last valid position (total - 1)
    @pl.when(j * page_size < total)
    def _compute():
        c = q_ref.shape[0]
        q = q_ref[:, 0].astype(jnp.float32).reshape(c * g, -1) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # [page, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [C*G, page]
        rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + j * page_size
        # causal within the chunk (query i sits at position start + i) and
        # clipped to the valid cache; padding rows end up fully masked
        s = jnp.where((cols <= start + rows) & (cols < total), s, NEG_INF)
        m_prev = m_ref[...]                                   # [C*G, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        c = q_ref.shape[0]
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[:, 0] = out.reshape(c, g, -1).astype(o_ref.dtype)


def paged_prefill_attention_fwd(q, k_pages, v_pages, page_row, start,
                                total_len, *, interpret=False):
    """Chunked-prefill attention for ONE sequence against its paged cache.

    q [C, Hq, D] (the chunk's queries; row i sits at position start + i);
    k/v_pages [P, page, Hkv, D] — the chunk's K/V must already be written
    into the pages; page_row [max_pages]; start / total_len scalars with
    total_len = start + valid tokens in the chunk. -> [C, Hq, D]. Rows at or
    past total_len are padding: they attend to the valid prefix and return
    well-defined garbage the caller ignores.
    """
    c, hq, d = q.shape
    _, page_size, hkv, _ = k_pages.shape
    g = hq // hkv
    assert hq == g * hkv, (hq, hkv)
    max_pages = page_row.shape[0]
    scale = 1.0 / (d ** 0.5)

    qg = q.reshape(c, hkv, g, d)
    pt = page_row.astype(jnp.int32)
    meta = jnp.stack([jnp.asarray(start, jnp.int32),
                      jnp.asarray(total_len, jnp.int32)])

    kern = functools.partial(_paged_prefill_kernel, page_size=page_size,
                             g=g, scale=scale)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(hkv, max_pages),
            in_specs=[
                pl.BlockSpec((c, 1, g, d), lambda h, j, pt, meta: (0, h, 0, 0)),
                pl.BlockSpec((1, page_size, 1, d),
                             lambda h, j, pt, meta: (pt[j], 0, h, 0)),
                pl.BlockSpec((1, page_size, 1, d),
                             lambda h, j, pt, meta: (pt[j], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((c, 1, g, d),
                                   lambda h, j, pt, meta: (0, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((c * g, d), jnp.float32),
                pltpu.VMEM((c * g, 1), jnp.float32),
                pltpu.VMEM((c * g, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((c, hkv, g, d), q.dtype),
        interpret=interpret,
    )(pt, meta, qg, k_pages, v_pages)
    return out.reshape(c, hq, d)


def paged_decode_attention_fwd(q, k_pages, v_pages, page_table, seq_lens, *,
                               interpret=False):
    """q [B, Hq, D]; k/v_pages [P, page, Hkv, D]; page_table [B, max_pages];
    seq_lens [B] -> [B, Hq, D]. Decode is forward-only: no VJP."""
    b, hq, d = q.shape
    _, page_size, hkv, _ = k_pages.shape
    g = hq // hkv
    assert hq == g * hkv, (hq, hkv)
    max_pages = page_table.shape[1]
    scale = 1.0 / (d ** 0.5)

    qg = q.reshape(b, hkv, g, d)
    pt = page_table.astype(jnp.int32)
    sl = seq_lens.astype(jnp.int32)

    kern = functools.partial(_paged_decode_kernel, page_size=page_size,
                             scale=scale)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, hkv, max_pages),
            in_specs=[
                pl.BlockSpec((1, 1, g, d), lambda bi, h, j, pt, sl: (bi, h, 0, 0)),
                pl.BlockSpec((1, page_size, 1, d),
                             lambda bi, h, j, pt, sl: (pt[bi, j], 0, h, 0)),
                pl.BlockSpec((1, page_size, 1, d),
                             lambda bi, h, j, pt, sl: (pt[bi, j], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda bi, h, j, pt, sl: (bi, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, d), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(pt, sl, qg, k_pages, v_pages)
    return out.reshape(b, hq, d)
