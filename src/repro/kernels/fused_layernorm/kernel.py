"""Pallas TPU kernel: fused residual-add + LayerNorm/RMSNorm.

Unfused, this chain is 4 HBM passes (add out, mean/var reduce, normalize read,
write); fused it is one read of (x, residual) and one write of y, with the
row statistics living in VMEM — the 6-8x traffic reduction the paper measures
in Fig 13. Rows are tiled [TILE_R, D]; D must fit VMEM (all assigned archs:
d_model <= 12288 -> <= 96 KiB fp32 per row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 256


def _ln_kernel(x_ref, res_ref, scale_ref, bias_ref, y_ref, *, eps, rms):
    h = x_ref[...].astype(jnp.float32) + res_ref[...].astype(jnp.float32)
    if rms:
        var = jnp.mean(h * h, axis=-1, keepdims=True)
        y = h * jax.lax.rsqrt(var + eps)
    else:
        mu = jnp.mean(h, axis=-1, keepdims=True)
        c = h - mu
        var = jnp.mean(c * c, axis=-1, keepdims=True)
        y = c * jax.lax.rsqrt(var + eps)
    y = y * scale_ref[...].astype(jnp.float32)
    if bias_ref is not None:
        y = y + bias_ref[...].astype(jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)


def fused_residual_layernorm(x, residual, scale, bias=None, *, eps=1e-5,
                             rms: bool = False, interpret: bool = False):
    """x, residual: [R, D]; scale/bias: [D]."""
    r, d = x.shape
    tile = min(TILE_R, r)
    assert r % tile == 0, (r, tile)
    row = pl.BlockSpec((tile, d), lambda i: (i, 0))
    vec = pl.BlockSpec((d,), lambda i: (0,))
    args = [x, residual, scale]
    in_specs = [row, row, vec]
    if bias is not None:
        args.append(bias)
        in_specs.append(vec)
        kern = functools.partial(_ln_kernel, eps=eps, rms=rms)
    else:
        kern = functools.partial(
            lambda xr, rr, sr, yr, *, eps, rms:
            _ln_kernel(xr, rr, sr, None, yr, eps=eps, rms=rms),
            eps=eps, rms=rms)
    return pl.pallas_call(
        kern,
        # jaxlint: allow[pallas-grid-floordiv] r % tile asserted above
        grid=(r // tile,),
        in_specs=in_specs,
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(*args)
