"""Pallas TPU kernel: fused residual-add + LayerNorm/RMSNorm.

Unfused, this chain is 4 HBM passes (add out, mean/var reduce, normalize read,
write); fused it is one read of (x, residual) and one write of y, with the
row statistics living in VMEM — the 6-8x traffic reduction the paper measures
in Fig 13. Rows are tiled [TILE_R, D]; D must fit VMEM (all assigned archs:
d_model <= 12288 -> <= 96 KiB fp32 per row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 256


def _ln_kernel(x_ref, res_ref, scale_ref, bias_ref, y_ref, *, eps, rms):
    h = x_ref[...].astype(jnp.float32) + res_ref[...].astype(jnp.float32)
    if rms:
        var = jnp.mean(h * h, axis=-1, keepdims=True)
        y = h * jax.lax.rsqrt(var + eps)
    else:
        mu = jnp.mean(h, axis=-1, keepdims=True)
        c = h - mu
        var = jnp.mean(c * c, axis=-1, keepdims=True)
        y = c * jax.lax.rsqrt(var + eps)
    y = y * scale_ref[...].astype(jnp.float32)
    if bias_ref is not None:
        y = y + bias_ref[...].astype(jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)


def fused_residual_layernorm(x, residual, scale, bias=None, *, eps=1e-5,
                             rms: bool = False, interpret: bool = False):
    """x, residual: [R, D]; scale/bias: [D]."""
    r, d = x.shape
    tile = min(TILE_R, r)
    assert r % tile == 0, (r, tile)
    row = pl.BlockSpec((tile, d), lambda i: (i, 0))
    vec = pl.BlockSpec((d,), lambda i: (0,))
    args = [x, residual, scale]
    in_specs = [row, row, vec]
    if bias is not None:
        args.append(bias)
        in_specs.append(vec)
        kern = functools.partial(_ln_kernel, eps=eps, rms=rms)
    else:
        kern = functools.partial(
            lambda xr, rr, sr, yr, *, eps, rms:
            _ln_kernel(xr, rr, sr, None, yr, eps=eps, rms=rms),
            eps=eps, rms=rms)
    return pl.pallas_call(
        kern,
        # jaxlint: allow[pallas-grid-floordiv] r % tile asserted above
        grid=(r // tile,),
        in_specs=in_specs,
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(*args)


def _resnorm_kernel(y_ref, x_ref, scale_ref, bias_ref, h_ref, xo_ref, *,
                    eps, kind):
    # model-dtype add (bit-faithful to the unfused `x = x + y`), fp32 stats
    x2 = x_ref[...] + y_ref[...]
    xf = x2.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        h = xf * jax.lax.rsqrt(var + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        h = (xf - mu) * jax.lax.rsqrt(var + eps)
    h = h * scale_ref[...].astype(jnp.float32)
    if bias_ref is not None:
        h = h + bias_ref[...].astype(jnp.float32)
    h_ref[...] = h.astype(h_ref.dtype)
    xo_ref[...] = x2


def decode_residual_norm(y, x, scale, bias=None, *, eps=1e-5,
                         kind: str = "rmsnorm", interpret: bool = False):
    """Decode-shaped fused residual+norm: y, x [R, D] -> (normed [R, D],
    x+y [R, D]). One read of (y, x), one write of each output — the decode
    layer's three residual-stream HBM round-trips become one."""
    r, d = x.shape
    tile = min(TILE_R, r)
    assert r % tile == 0, (r, tile)
    row = pl.BlockSpec((tile, d), lambda i: (i, 0))
    vec = pl.BlockSpec((d,), lambda i: (0,))
    args = [y, x, scale]
    in_specs = [row, row, vec]
    if bias is not None:
        args.append(bias)
        in_specs.append(vec)
        kern = functools.partial(_resnorm_kernel, eps=eps, kind=kind)
    else:
        kern = functools.partial(
            lambda yr, xr, sr, hr, xo, *, eps, kind:
            _resnorm_kernel(yr, xr, sr, None, hr, xo, eps=eps, kind=kind),
            eps=eps, kind=kind)
    return pl.pallas_call(
        kern,
        # jaxlint: allow[pallas-grid-floordiv] r % tile asserted above
        grid=(r // tile,),
        in_specs=in_specs,
        out_specs=[row, row],
        out_shape=[jax.ShapeDtypeStruct((r, d), x.dtype),
                   jax.ShapeDtypeStruct((r, d), x.dtype)],
        interpret=interpret,
    )(*args)


def _gated_kernel(y_ref, z_ref, scale_ref, o_ref, *, eps):
    y = y_ref[...]
    z = z_ref[...]
    yf = (y * (z * jax.nn.sigmoid(z))).astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    o_ref[...] = (yf * jax.lax.rsqrt(var + eps)
                  * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def gated_rmsnorm(y, z, scale, *, eps=1e-5, interpret: bool = False):
    """SiLU-gated RMSNorm (mamba mixer epilogue): y, z [R, C] -> [R, C],
    gate + stats + normalize in one VMEM pass."""
    r, d = y.shape
    tile = min(TILE_R, r)
    assert r % tile == 0, (r, tile)
    row = pl.BlockSpec((tile, d), lambda i: (i, 0))
    vec = pl.BlockSpec((d,), lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_gated_kernel, eps=eps),
        # jaxlint: allow[pallas-grid-floordiv] r % tile asserted above
        grid=(r // tile,),
        in_specs=[row, row, vec],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((r, d), y.dtype),
        interpret=interpret,
    )(y, z, scale)
