"""Oracle for the fused residual+LayerNorm kernel (paper Fig 13 'LN' fusion)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def fused_residual_layernorm(x, residual, scale, bias=None, *, eps=1e-5,
                             rms: bool = False):
    """y = norm(x + residual) * scale (+ bias); stats in fp32."""
    h = (x.astype(jnp.float32) + residual.astype(jnp.float32))
    if rms:
        var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
        y = h * jax.lax.rsqrt(var + eps)
    else:
        mu = jnp.mean(h, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
        y = (h - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)
