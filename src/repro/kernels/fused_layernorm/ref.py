"""Oracles for the fused residual+norm kernels (paper Fig 13 'LN' fusion).

Two flavors with different numerics contracts:

* :func:`fused_residual_layernorm` — the training/prefill fusion: the
  residual add runs in fp32 (numerics-*improving* vs the unfused bf16 add),
  so its parity tests are tolerance-based.
* :func:`decode_residual_norm` / :func:`gated_rmsnorm` — the decode-path
  fusions: the add stays in the MODEL dtype and the norm duplicates
  ``models.layers._apply_norm`` / ``models.ssm._gated_rmsnorm`` operation
  for operation (duplicated here rather than imported to keep the kernels
  layer import-cycle-free), so the fused decode stack is BIT-identical to
  the unfused one — the property the engine's ``fused_decode`` flag
  guarantees. Input shapes are preserved (no flattening) so the fp32 row
  reductions see exactly the shapes the unfused path reduces.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def fused_residual_layernorm(x, residual, scale, bias=None, *, eps=1e-5,
                             rms: bool = False):
    """y = norm(x + residual) * scale (+ bias); stats in fp32."""
    h = (x.astype(jnp.float32) + residual.astype(jnp.float32))
    if rms:
        var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
        y = h * jax.lax.rsqrt(var + eps)
    else:
        mu = jnp.mean(h, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
        y = (h - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def _norm(kind: str, x, scale, bias, eps):
    """Verbatim ``models.layers._apply_norm`` math (see module docstring
    for why it is duplicated instead of imported)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def decode_residual_norm(y, x, scale, bias=None, *, kind: str = "rmsnorm",
                         eps=1e-5):
    """Fused ``x += y; h = norm(x)`` of the decode residual stream ->
    ``(h, x_new)``. The add runs in the model dtype and the norm is the
    verbatim ``_apply_norm`` math, so the pair is bit-identical to the
    unfused two-op sequence on every backend."""
    x2 = x + y
    return _norm(kind, x2, scale, bias, eps), x2


def gated_rmsnorm(y, z, scale, eps=1e-5):
    """Verbatim ``models.ssm._gated_rmsnorm``: SiLU-gated RMSNorm of the
    mamba mixer output (the canonical definition — ``models.ssm`` delegates
    here, and the Pallas kernel must match it bit-for-bit)."""
    yf = (y * (z * jax.nn.sigmoid(z))).astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(y.dtype)
