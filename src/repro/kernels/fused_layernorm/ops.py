"""jit'd wrapper for the fused residual+norm kernel; falls back to ref off-TPU."""
from __future__ import annotations

import jax

from . import kernel, ref


def supported() -> bool:
    return jax.default_backend() == "tpu"


def fused_residual_layernorm(x, residual, scale, bias=None, *, eps=1e-5,
                             rms: bool = False, interpret: bool = False):
    if not (supported() or interpret):
        return ref.fused_residual_layernorm(x, residual, scale, bias,
                                            eps=eps, rms=rms)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    r2 = residual.reshape(-1, shape[-1])
    y = kernel.fused_residual_layernorm(x2, r2, scale, bias, eps=eps,
                                        rms=rms, interpret=interpret)
    return y.reshape(shape)
