"""jit'd wrapper for the fused residual+norm kernel; falls back to ref off-TPU."""
from __future__ import annotations

import jax

from . import kernel, ref


def supported() -> bool:
    return jax.default_backend() == "tpu"


def fused_residual_layernorm(x, residual, scale, bias=None, *, eps=1e-5,
                             rms: bool = False, interpret: bool = False):
    if not (supported() or interpret):
        return ref.fused_residual_layernorm(x, residual, scale, bias,
                                            eps=eps, rms=rms)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    r2 = residual.reshape(-1, shape[-1])
    y = kernel.fused_residual_layernorm(x2, r2, scale, bias, eps=eps,
                                        rms=rms, interpret=interpret)
    return y.reshape(shape)


def decode_residual_norm(y, x, scale, bias=None, *, kind: str = "rmsnorm",
                         eps=1e-5, interpret: bool = False):
    """Fused decode-path ``x += y; h = norm(x)`` -> ``(h, x_new)``, any
    leading shape with D last. Bit-identical to the unfused two-op sequence
    (model-dtype add, verbatim ``_apply_norm`` math — see ``ref.py``); the
    Pallas path keeps the residual stream VMEM-resident."""
    if not (supported() or interpret):
        return ref.decode_residual_norm(y, x, scale, bias, kind=kind,
                                        eps=eps)
    shape = x.shape
    h, x2 = kernel.decode_residual_norm(
        y.reshape(-1, shape[-1]), x.reshape(-1, shape[-1]), scale, bias,
        eps=eps, kind=kind, interpret=interpret)
    return h.reshape(shape), x2.reshape(shape)


def gated_rmsnorm(y, z, scale, *, eps=1e-5, interpret: bool = False):
    """SiLU-gated RMSNorm (the mamba mixer epilogue), any leading shape
    with the channel dim last. Canonical semantics in ``ref.gated_rmsnorm``
    (``models.ssm`` delegates there); the Pallas path fuses gate + stats +
    normalize into one VMEM pass."""
    if not (supported() or interpret):
        return ref.gated_rmsnorm(y, z, scale, eps=eps)
    shape = y.shape
    out = kernel.gated_rmsnorm(y.reshape(-1, shape[-1]),
                               z.reshape(-1, shape[-1]), scale, eps=eps,
                               interpret=interpret)
    return out.reshape(shape)
