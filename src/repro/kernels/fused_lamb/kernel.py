"""Pallas TPU kernels for fused LAMB (paper Fig 3 / Fig 13's "fused optimizer").

Two kernels, matching the paper's LAMB Stage 1 / Stage 2 split:

  stage1: one HBM pass reading (w, g, m, v) and writing (m', v', u) + per-tile
          partial sums of ||w||^2 and ||u||^2 — everything the trust ratio needs.
  stage2: one HBM pass applying w' = w - lr * r * u.

Total traffic: 4 reads + 4 writes of model-size arrays vs ~11 passes unfused —
this is exactly the Takeaway-8 "LAMB reads 4x the model size" bottleneck the
paper says accelerators must optimize.

Layout: flat [rows, F] fp32 (the ZeRO state layout); grid tiles F with the rows
axis as the leading grid dim so per-row partial norms land in [rows, tiles].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_F = 2048  # lane-aligned (128) and small enough for 6 operands in VMEM


def _stage1_kernel(w_ref, g_ref, m_ref, v_ref, scal_ref,
                   m_out, v_out, u_out, wsq_out, usq_out,
                   *, beta1, beta2, eps, weight_decay):
    ginv = scal_ref[0]
    c1 = scal_ref[1]
    c2 = scal_ref[2]
    w = w_ref[...]
    gn = g_ref[...] * ginv
    m_new = beta1 * m_ref[...] + (1.0 - beta1) * gn
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * gn * gn
    u = (m_new * c1) / (jnp.sqrt(v_new * c2) + eps) + weight_decay * w
    m_out[...] = m_new
    v_out[...] = v_new
    u_out[...] = u
    wsq_out[0, 0] = jnp.sum(w * w)
    usq_out[0, 0] = jnp.sum(u * u)


def _stage2_kernel(w_ref, u_ref, r_ref, w_out, *, lr):
    w_out[...] = w_ref[...] - lr * r_ref[0] * u_ref[...]


def lamb_stage1(w, g, m, v, scalars, *, beta1, beta2, eps, weight_decay,
                interpret: bool = False):
    """w/g/m/v: [R, F] fp32 (F % TILE_F == 0); scalars: [3] (ginv, c1, c2)."""
    r, f = w.shape
    assert f % TILE_F == 0, (f, TILE_F)
    tiles = f // TILE_F
    grid = (r, tiles)
    row_tile = pl.BlockSpec((1, TILE_F), lambda i, j: (i, j))
    scal = pl.BlockSpec((3,), lambda i, j: (0,))
    part = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    kernel = functools.partial(_stage1_kernel, beta1=beta1, beta2=beta2,
                               eps=eps, weight_decay=weight_decay)
    m_new, v_new, u, wsq, usq = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[row_tile, row_tile, row_tile, row_tile, scal],
        out_specs=[row_tile, row_tile, row_tile, part, part],
        out_shape=[
            jax.ShapeDtypeStruct((r, f), jnp.float32),
            jax.ShapeDtypeStruct((r, f), jnp.float32),
            jax.ShapeDtypeStruct((r, f), jnp.float32),
            jax.ShapeDtypeStruct((r, tiles), jnp.float32),
            jax.ShapeDtypeStruct((r, tiles), jnp.float32),
        ],
        interpret=interpret,
    )(w, g, m, v, scalars)
    return m_new, v_new, u, wsq, usq


def lamb_stage2(w, u, rr, *, lr, interpret: bool = False):
    """w/u: [R, F]; rr: [R, 1] per-row trust ratios."""
    r, f = w.shape
    tiles = f // TILE_F
    row_tile = pl.BlockSpec((1, TILE_F), lambda i, j: (i, j))
    rspec = pl.BlockSpec((1, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        functools.partial(_stage2_kernel, lr=lr),
        grid=(r, tiles),
        in_specs=[row_tile, row_tile, rspec],
        out_specs=row_tile,
        out_shape=jax.ShapeDtypeStruct((r, f), jnp.float32),
        interpret=interpret,
    )(w, u, rr)
