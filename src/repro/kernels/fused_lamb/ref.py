"""Pure-jnp oracle for the fused LAMB kernels (paper Fig 3, Stage 1 + 2)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def lamb_stage1(w, g, m, v, *, ginv, c1, c2, beta1, beta2, eps, weight_decay):
    """-> (m', v', u) — the update direction before the trust ratio."""
    gn = g.astype(jnp.float32) * ginv
    m_new = beta1 * m + (1.0 - beta1) * gn
    v_new = beta2 * v + (1.0 - beta2) * jnp.square(gn)
    u = (m_new * c1) / (jnp.sqrt(v_new * c2) + eps) + weight_decay * w
    return m_new, v_new, u


def lamb_stage2(w, u, *, lr, r):
    """w' = w - lr * r * u with r broadcast per row."""
    return w - lr * r * u


def lamb_stage12(w, g, m, v, *, ginv, c1, c2, beta1, beta2, eps,
                 weight_decay, lr, red_axes=(-1,)):
    """Full Fig-3 update on [rows..., F] arrays; trust ratio per row."""
    m_new, v_new, u = lamb_stage1(w, g, m, v, ginv=ginv, c1=c1, c2=c2,
                                  beta1=beta1, beta2=beta2, eps=eps,
                                  weight_decay=weight_decay)
    wn = jnp.sqrt(jnp.sum(jnp.square(w), axis=red_axes, keepdims=True))
    un = jnp.sqrt(jnp.sum(jnp.square(u), axis=red_axes, keepdims=True))
    r = jnp.where((wn > 0) & (un > 0), wn / jnp.maximum(un, 1e-30), 1.0)
    return lamb_stage2(w, u, lr=lr, r=r), m_new, v_new
