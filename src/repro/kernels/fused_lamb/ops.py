"""jit'd wrapper: full Fig-3 LAMB update via the two Pallas kernels.

Pads the flat axis to the kernel tile, runs stage1 (update direction + partial
norms), combines the per-tile norms into per-row trust ratios, runs stage2.
Falls back to the pure-jnp reference off-TPU unless ``interpret=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def supported() -> bool:
    return jax.default_backend() == "tpu"


def lamb_stage12(w, g, m, v, *, ginv, c1, c2, beta1, beta2, eps,
                 weight_decay, lr, red_axes=(-1,), interpret: bool = False):
    if not (supported() or interpret):
        return ref.lamb_stage12(w, g, m, v, ginv=ginv, c1=c1, c2=c2,
                                beta1=beta1, beta2=beta2, eps=eps,
                                weight_decay=weight_decay, lr=lr,
                                red_axes=red_axes)
    shape = w.shape
    w2 = w.reshape(-1, shape[-1]).astype(jnp.float32)
    g2 = g.reshape(-1, shape[-1]).astype(jnp.float32)
    m2 = m.reshape(-1, shape[-1]).astype(jnp.float32)
    v2 = v.reshape(-1, shape[-1]).astype(jnp.float32)
    f = w2.shape[-1]
    pad = (-f) % kernel.TILE_F
    if pad:
        w2, g2, m2, v2 = (jnp.pad(a, ((0, 0), (0, pad)))
                          for a in (w2, g2, m2, v2))
    scalars = jnp.stack([jnp.asarray(ginv, jnp.float32),
                         jnp.asarray(c1, jnp.float32),
                         jnp.asarray(c2, jnp.float32)])
    m_new, v_new, u, wsq, usq = kernel.lamb_stage1(
        w2, g2, m2, v2, scalars, beta1=beta1, beta2=beta2, eps=eps,
        weight_decay=weight_decay, interpret=interpret)
    wn = jnp.sqrt(jnp.sum(wsq, axis=-1, keepdims=True))
    un = jnp.sqrt(jnp.sum(usq, axis=-1, keepdims=True))
    rr = jnp.where((wn > 0) & (un > 0), wn / jnp.maximum(un, 1e-30), 1.0)
    w_new = kernel.lamb_stage2(w2, u, rr, lr=lr, interpret=interpret)
    if pad:
        w_new, m_new, v_new = (a[:, :f] for a in (w_new, m_new, v_new))
    return (w_new.reshape(shape), m_new.reshape(shape), v_new.reshape(shape))
