"""Three-term roofline model over compiled dry-run artifacts (TPU v5e target).

    compute_s    = HLO_FLOPs_per_device / peak_flops
    memory_s     = HLO_bytes_per_device / hbm_bw
    collective_s = collective_bytes_per_device / link_bw      (assignment formula)

plus a refined ``collective_wire_s`` that applies ring-algorithm wire factors per
collective kind and routes pod-crossing groups over DCN. ``cost_analysis()`` on an
SPMD-partitioned module is already per-device, as is the HLO the collectives are
parsed from.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from ..configs.base import ArchConfig, ShapeConfig
from .hlotext import CollectiveSummary


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # B/s per chip
    ici_bw: float = 50e9                # B/s per ICI link (assignment constant)
    dcn_bw: float = 6.25e9              # B/s per chip across pods (~50 Gb/s)
    hbm_bytes: float = 16e9
    # per-kernel launch/latency floor: ~8us on the paper's GPU stack (the reason
    # its measured non-GEMM shares exceed a pure-bandwidth roofline); ~0 on TPU
    # where the whole step is one fused XLA program
    kernel_overhead: float = 0.0
    # achieved fraction of peak bandwidth for strided/small EW kernels
    ew_bw_efficiency: float = 1.0


V5E = DeviceSpec()

# the paper's profiling GPU, for Fig 4/5-style breakdown comparisons
MI100 = DeviceSpec(name="mi100", peak_flops=184.6e12, hbm_bw=1228e9,
                   ici_bw=32e9, hbm_bytes=32e9,
                   kernel_overhead=8e-6, ew_bw_efficiency=0.6)
MI100_FP32 = DeviceSpec(name="mi100-fp32", peak_flops=23.1e12, hbm_bw=1228e9,
                        ici_bw=32e9, hbm_bytes=32e9,
                        kernel_overhead=8e-6, ew_bw_efficiency=0.6)


@dataclasses.dataclass
class RooflineTerms:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    collective_wire_s: float
    dominant: str
    model_flops: float
    useful_ratio: float                  # MODEL_FLOPS / (HLO flops * n_devices)
    step_s: float                        # max of the three terms
    peak_fraction: float                 # model_flops / (chips*peak) / step_s

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def matmul_params(arch: ArchConfig) -> float:
    """Active params that participate in GEMMs (embedding lookup excluded)."""
    from ..models.layers import pad_vocab
    active = arch.param_count(active_only=True)
    emb = pad_vocab(arch.vocab_size) * arch.d_model
    if arch.tie_embeddings:
        return float(active)            # the single table is also the head matmul
    return float(active - emb)          # drop the lookup-only embedding table


def model_flops(arch: ArchConfig, shape: ShapeConfig) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = active matmul params."""
    p = matmul_params(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * p * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * p * tokens
    tokens = shape.global_batch          # decode: one token per sequence
    return 2.0 * p * tokens


def compute_terms(*, flops_per_device: float, bytes_per_device: float,
                  colls: CollectiveSummary, n_devices: int,
                  arch: ArchConfig, shape: ShapeConfig,
                  dev: DeviceSpec = V5E) -> RooflineTerms:
    compute_s = flops_per_device / dev.peak_flops
    memory_s = bytes_per_device / dev.hbm_bw
    collective_s = colls.operand_bytes / dev.ici_bw
    wire_s = colls.wire_bytes_ici / dev.ici_bw + colls.wire_bytes_dcn / dev.dcn_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": max(collective_s, wire_s)}
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    total_flops = flops_per_device * n_devices
    useful = mf / total_flops if total_flops else 0.0
    step_s = max(terms.values())
    ideal_s = mf / (n_devices * dev.peak_flops)
    return RooflineTerms(
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        collective_bytes_per_device=colls.operand_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        collective_wire_s=wire_s, dominant=dominant, model_flops=mf,
        useful_ratio=useful, step_s=step_s,
        peak_fraction=(ideal_s / step_s) if step_s > 0 else 0.0)
