"""Analytical multi-device training profiles — the paper's §4.1.1 methodology.

The paper constructs per-device distributed profiles from single-device
measurements plus a ring-AllReduce communication model; we do the same from the
analytical inventory, reproducing Fig 12's five configurations:

  S1  single device, B=16
  D1  data parallel, B=16/device, gradient all-reduce overlapped per layer
  D2  data parallel, no overlap (all gradients communicated after backprop)
  M1  2-way Megatron intra-layer model parallel
  M2  8-way model parallel, B scaled to 64

plus the modern v5e variants used by EXPERIMENTS.md. Communication: ring
all-reduce moves 2(g-1)/g * bytes per device at ``link_bw``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from ..configs.base import ArchConfig
from .analytical import phase_times
from .roofline import DeviceSpec, V5E, MI100_FP32


def ring_allreduce_time(bytes_per_device: float, group: int,
                        link_bw: float) -> float:
    if group <= 1:
        return 0.0
    return 2.0 * (group - 1) / group * bytes_per_device / link_bw


@dataclasses.dataclass
class DistProfile:
    name: str
    phase_times: Dict[str, float]
    comm_time: float
    comm_bytes: float

    @property
    def total(self) -> float:
        return sum(self.phase_times.values()) + self.comm_time

    def breakdown(self) -> Dict[str, float]:
        out = dict(self.phase_times)
        out["communication"] = self.comm_time
        return out


def data_parallel(arch: ArchConfig, batch: int, seq: int, devices: int,
                  overlap: bool, dev: DeviceSpec = MI100_FP32,
                  dtype_bytes: int = 4) -> DistProfile:
    """Paper D1/D2: model replicated; per-device compute == single device;
    gradient ring all-reduce, optionally overlapped layer-by-layer with bwd."""
    times = phase_times(arch, batch, seq, dev, dtype_bytes)
    grad_bytes = arch.param_count() * dtype_bytes
    t_comm = ring_allreduce_time(grad_bytes, devices, dev.ici_bw)
    if overlap:
        # per-layer comms overlap with the next layer's bwd compute (paper:
        # max(comp, comm) pairwise) — only the first layer's reduce is exposed
        bwd_compute = sum(v for k, v in times.items() if k != "lamb") * (2 / 3)
        exposed = max(t_comm - bwd_compute, t_comm / arch.num_layers)
        t_comm = exposed
    return DistProfile(
        name=f"DP{'+ov' if overlap else ''} x{devices}",
        phase_times=times, comm_time=t_comm, comm_bytes=grad_bytes)


def model_parallel(arch: ArchConfig, batch: int, seq: int, mp: int,
                   dev: DeviceSpec = MI100_FP32,
                   dtype_bytes: int = 4) -> DistProfile:
    """Paper M1/M2 (Megatron intra-layer): per-device GEMM dims /mp; LAMB /mp;
    4 serialized activation all-reduces per transformer layer (2 fwd + 2 bwd)."""
    import dataclasses as dc
    shrunk = dc.replace(
        arch,
        d_ff=arch.d_ff // mp,
        num_heads=max(arch.num_heads // mp, 1) if arch.num_heads else 0,
        num_kv_heads=max(arch.num_kv_heads // mp, 1) if arch.num_kv_heads else 0,
        head_dim=arch.resolved_head_dim)
    times = phase_times(shrunk, batch, seq, dev, dtype_bytes)
    # LAMB scales with the local parameter count
    for k in list(times):
        if k == "lamb":
            times[k] = times[k] / mp
    act_bytes = batch * seq * arch.d_model * dtype_bytes
    t_comm = 4 * arch.num_layers * ring_allreduce_time(act_bytes, mp,
                                                       dev.ici_bw)
    return DistProfile(name=f"MP x{mp}", phase_times=times,
                       comm_time=t_comm,
                       comm_bytes=4 * arch.num_layers * act_bytes)


def single(arch: ArchConfig, batch: int, seq: int,
           dev: DeviceSpec = MI100_FP32, dtype_bytes: int = 4) -> DistProfile:
    return DistProfile(name=f"Single B={batch}",
                       phase_times=phase_times(arch, batch, seq, dev,
                                               dtype_bytes),
                       comm_time=0.0, comm_bytes=0.0)


def figure12(arch: ArchConfig, seq: int = 128) -> Dict[str, DistProfile]:
    """The paper's Fig 12 set: S1, D1, D2 (64-way), M1 (2-way), M2 (8-way)."""
    return {
        "S1 (single, B=16)": single(arch, 16, seq),
        "D1 (DP64 B=16, overlap)": data_parallel(arch, 16, seq, 64, True),
        "D2 (DP64 B=16, no overlap)": data_parallel(arch, 16, seq, 64, False),
        "M1 (MP2, B=16)": model_parallel(arch, 16, seq, 2),
        "M2 (MP8, B=64)": model_parallel(arch, 64, seq, 8),
    }
