"""HLO-text parsing: collective inventory, op taxonomy, fusion counts.

The compiled module of an SPMD program is the *per-device* program; shapes here are
per-device shards. Collective wire-byte models (ring algorithms):

    all-reduce       2 (g-1)/g * bytes      (reduce-scatter + all-gather phases)
    all-gather       (g-1)/g   * out_bytes
    reduce-scatter   (g-1)/g   * in_bytes
    all-to-all       (g-1)/g   * bytes
    collective-permute bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast", "ragged-all-to-all")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")


def shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    operand_bytes: int
    group_size: int
    crosses_pod: bool
    name: str

    @property
    def wire_bytes(self) -> float:
        g = max(self.group_size, 1)
        if g == 1:
            return 0.0
        frac = (g - 1) / g
        if self.kind == "all-reduce":
            return 2.0 * frac * self.operand_bytes
        if self.kind == "all-gather":
            return frac * self.result_bytes
        if self.kind == "reduce-scatter":
            return frac * self.operand_bytes
        if self.kind in ("all-to-all", "ragged-all-to-all"):
            return frac * self.operand_bytes
        if self.kind == "collective-broadcast":
            return self.result_bytes
        return float(self.operand_bytes)   # collective-permute


@dataclasses.dataclass
class CollectiveSummary:
    ops: List[CollectiveOp]

    @property
    def operand_bytes(self) -> float:
        return float(sum(o.operand_bytes for o in self.ops))

    @property
    def result_bytes(self) -> float:
        return float(sum(o.result_bytes for o in self.ops))

    @property
    def wire_bytes_ici(self) -> float:
        return float(sum(o.wire_bytes for o in self.ops if not o.crosses_pod))

    @property
    def wire_bytes_dcn(self) -> float:
        return float(sum(o.wire_bytes for o in self.ops if o.crosses_pod))

    def by_kind(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for o in self.ops:
            d = out.setdefault(o.kind, {"count": 0, "operand_bytes": 0.0,
                                        "wire_bytes": 0.0})
            d["count"] += 1
            d["operand_bytes"] += o.operand_bytes
            d["wire_bytes"] += o.wire_bytes
        return out

    def to_dict(self) -> Dict:
        return {"operand_bytes": self.operand_bytes,
                "result_bytes": self.result_bytes,
                "wire_bytes_ici": self.wire_bytes_ici,
                "wire_bytes_dcn": self.wire_bytes_dcn,
                "count": len(self.ops),
                "by_kind": self.by_kind()}


def _build_def_table(text: str) -> Dict[str, str]:
    """op name -> result type string."""
    table: Dict[str, str] = {}
    for line in text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # rhs starts with the result type, e.g. "f32[64,1024]{1,0} all-reduce(..."
        tm = re.match(r"^(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)", rhs)
        if tm:
            table[name] = tm.group(1)
    return table


def _group_size(line: str, n_devices: int) -> Tuple[int, bool]:
    """(group size, crosses_pod?) — pod-crossing detected from device-id stride."""
    m = _GROUPS_RE.search(line)
    if m:
        n_groups, g = int(m.group(1)), int(m.group(2))
        # iota groups [n,g]<=[N] fill contiguously: within-pod iff the whole group
        # fits inside one 256-device pod
        crosses = g > 256 or (n_devices > 256 and n_groups * g > 256 and
                              _iota_crosses_pod(line, g))
        return g, crosses
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{ ")
        ids = [int(x) for x in first.split(",") if x.strip() != ""]
        crosses = len({i // 256 for i in ids}) > 1 if ids else False
        return max(len(ids), 1), crosses
    return n_devices, n_devices > 256


def _iota_crosses_pod(line: str, g: int) -> bool:
    # replica_groups=[n,g]<=[a,b,...]T(perm) iota form: conservatively assume a
    # group crosses pods when its index-space span exceeds one pod
    m = re.search(r"<=\[([\d,]+)\]", line)
    if not m:
        return False
    dims = [int(x) for x in m.group(1).split(",")]
    total = 1
    for d in dims:
        total *= d
    # contiguous iota: group stride = total / n_groups
    return g > 1 and total > 256 and (total // max(total // g // 1, 1)) > 256


def parse_collectives(text: str, n_devices: int) -> CollectiveSummary:
    table = _build_def_table(text)
    ops: List[CollectiveOp] = []
    seen_names = set()
    for line in text.splitlines():
        stripped = line.strip()
        m = _DEF_RE.match(stripped)
        if not m:
            continue
        name, rhs = m.groups()
        kind = None
        for k in _COLL_KINDS:
            if re.search(rf"\s{k}(?:-start)?\(", rhs) or \
               rhs.split("}")[-1].lstrip().startswith(k):
                kind = k
                break
        if kind is None:
            continue
        if re.search(r"\b(all-reduce|all-gather|all-to-all|reduce-scatter|"
                     r"collective-permute)-done\b", rhs):
            continue                      # async pair: count the -start only
        if name in seen_names:
            continue
        seen_names.add(name)
        result_bytes = shape_bytes(table.get(name, rhs))
        # operands: names inside the call parens
        call = re.search(rf"{kind}(?:-start)?\(([^)]*)\)", rhs)
        operand_bytes = 0
        if call:
            for opnd in call.group(1).split(","):
                opnd = opnd.strip().lstrip("%")
                if opnd in table:
                    operand_bytes += shape_bytes(table[opnd])
        if operand_bytes == 0:
            operand_bytes = result_bytes
        g, crosses = _group_size(stripped, n_devices)
        ops.append(CollectiveOp(kind=kind, result_bytes=result_bytes,
                                operand_bytes=operand_bytes, group_size=g,
                                crosses_pod=crosses, name=name))
    return CollectiveSummary(ops)


# --------------------------------------------------------------- op taxonomy ------

_TAXONOMY_PATTERNS = (
    ("gemm", re.compile(r"\b(dot|convolution)\(")),
    ("collective", re.compile(r"\b(all-reduce|all-gather|reduce-scatter|"
                              r"all-to-all|collective-permute)(?:-start)?\(")),
    ("reduction", re.compile(r"\breduce(?:-window)?\(")),
    ("scatter_gather", re.compile(r"\b(scatter|gather|dynamic-slice|"
                                  r"dynamic-update-slice)\(")),
    ("elementwise_fusion", re.compile(r"\bfusion\(")),
    ("sort", re.compile(r"\bsort\(")),
)


def categorize_ops(text: str) -> Dict[str, int]:
    """Count HLO ops by the paper's taxonomy (GEMM / EW / reduction / ...)."""
    counts: Dict[str, int] = {}
    for line in text.splitlines():
        for cat, pat in _TAXONOMY_PATTERNS:
            if pat.search(line):
                counts[cat] = counts.get(cat, 0) + 1
                break
    return counts


def count_fusions(text: str) -> int:
    return len(re.findall(r"\bfusion\(", text))
