"""The paper's primary contribution: operator-level workload characterization.

hlotext      — HLO parsing: collective inventory, op taxonomy, fusion counts
roofline     — DeviceSpec + three-term roofline over compiled dry-run artifacts
analytical   — closed-form Table-3-style op inventory per architecture
characterize — paper-style runtime breakdowns (Figs 4/5/9/10) on a DeviceSpec
distmodel    — analytical DP/MP multi-device profiles (Fig 12, paper §4.1.1)
"""
from . import analytical, characterize, distmodel, hlotext, roofline

__all__ = ["analytical", "characterize", "distmodel", "hlotext", "roofline"]

