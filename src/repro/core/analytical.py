"""Closed-form operator inventory — the paper's Table 3, generalized.

For any ArchConfig x (batch, seq) this enumerates every GEMM with its
(M, N, K, batch) for FWD / BWD-grad-activation / BWD-grad-weight (exactly the
paper's three columns), plus the non-GEMM phases (LAMB stages, attention
softmax chain, GeLU/SwiGLU, dropout+residual+norm) with their FLOPs, bytes and
arithmetic intensity (Fig 7/8). Everything downstream — breakdown figures,
sweeps, the distributed model — consumes this inventory.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

from ..configs.base import ArchConfig
from .roofline import DeviceSpec, V5E


@dataclasses.dataclass
class Gemm:
    name: str
    layer: str                  # attn_linear | attn_bgemm | fc | moe | ssm | head
    m: int
    n: int
    k: int
    batch: int = 1
    count: int = 1              # per model per pass

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k * self.batch * self.count

    def bytes_(self, dtype_bytes: int = 2) -> float:
        per = (self.m * self.k + self.k * self.n + self.m * self.n)
        return per * self.batch * self.count * dtype_bytes

    def intensity(self, dtype_bytes: int = 2) -> float:
        return self.flops / max(self.bytes_(dtype_bytes), 1.0)


@dataclasses.dataclass
class EwOp:
    name: str
    layer: str                  # lamb | attn_softmax | activation | drn | loss
    flops: float
    bytes: float
    count: int = 1

    @property
    def total_flops(self) -> float:
        return self.flops * self.count

    @property
    def total_bytes(self) -> float:
        return self.bytes * self.count

    @property
    def intensity(self) -> float:
        return self.total_flops / max(self.total_bytes, 1.0)


def transformer_gemms(arch: ArchConfig, batch: int, seq: int,
                      phase: str = "fwd") -> List[Gemm]:
    """The paper's Table 3 rows for one pass over the whole model.

    phase: fwd | bwd_act | bwd_w (BWD rows transpose dims exactly as Table 3).
    """
    t = batch * seq                       # n*B, the token count
    d = arch.d_model
    hd = arch.resolved_head_dim
    out: List[Gemm] = []
    n_attn = sum(1 for i in range(arch.num_layers) if arch.is_attention_layer(i))
    n_moe = sum(1 for i in range(arch.num_layers) if arch.is_moe_layer(i))
    n_dense_mlp = (0 if arch.family == "ssm"
                   else arch.num_layers - n_moe)
    if arch.family == "encdec":
        n_attn += arch.enc_layers + arch.num_layers     # enc self + dec cross
        n_dense_mlp += arch.enc_layers

    def gemm(name, layer, m, n, k, b=1, count=1):
        if phase == "fwd":
            out.append(Gemm(name, layer, m, n, k, b, count))
        elif phase == "bwd_act":
            out.append(Gemm(name, layer, k, n, m, b, count))
        else:                           # bwd_w
            out.append(Gemm(name, layer, m, k, n, b, count))

    if arch.num_heads:
        # linear transforms (q, k, v fused + output projection)
        gemm("qkv_proj", "attn_linear", arch.q_dim + 2 * arch.kv_dim, t, d,
             count=n_attn)
        gemm("attn_out", "attn_linear", d, t, arch.q_dim, count=n_attn)
        # attention batched GEMMs (per the paper: B*h small GEMMs)
        gemm("attn_score", "attn_bgemm", seq, seq, hd,
             b=batch * arch.num_heads, count=n_attn)
        gemm("attn_pv", "attn_bgemm", hd, seq, seq,
             b=batch * arch.num_heads, count=n_attn)
    if n_dense_mlp:
        n_in = 3 if arch.mlp == "swiglu" else 1  # w1(+w3) count below
        gemm("fc1", "fc", arch.d_ff, t, d,
             count=n_dense_mlp * (2 if arch.mlp == "swiglu" else 1))
        gemm("fc2", "fc", d, t, arch.d_ff, count=n_dense_mlp)
    if n_moe:
        moe = arch.moe
        eff = moe.expert_ff or arch.d_ff
        cap_tokens = int(t * moe.top_k * moe.capacity_factor)
        gemm("moe_up", "moe", eff, cap_tokens, d,
             count=n_moe * (2 if arch.mlp == "swiglu" else 1))
        gemm("moe_down", "moe", d, cap_tokens, eff, count=n_moe)
        gemm("router", "moe", moe.num_experts, t, d, count=n_moe)
        if moe.num_shared_experts:
            sf = eff * moe.num_shared_experts
            gemm("moe_shared_up", "moe", sf, t, d, count=n_moe * 2)
            gemm("moe_shared_down", "moe", d, t, sf, count=n_moe)
    if arch.ssm is not None:
        from ..models import ssm as ssm_lib
        inner = ssm_lib.inner_dim(arch)
        h = ssm_lib.num_ssm_heads(arch)
        s_ = arch.ssm
        n_mamba = arch.num_layers - (n_attn if arch.family == "hybrid" else 0) \
            if arch.family in ("ssm", "hybrid") else 0
        if n_mamba:
            proj = 2 * inner + 2 * s_.ngroups * s_.state_dim + h
            gemm("ssm_in_proj", "ssm", proj, t, d, count=n_mamba)
            gemm("ssm_out_proj", "ssm", d, t, inner, count=n_mamba)
            q = min(s_.chunk, seq)
            nc = max(seq // q, 1)
            # SSD chunk GEMMs — the 'skinny' ones (paper Takeaway 7 analogue)
            gemm("ssd_scores", "ssm", q, q, s_.state_dim,
                 b=batch * nc * s_.ngroups, count=n_mamba)
            gemm("ssd_diag", "ssm", q, s_.head_dim, q,
                 b=batch * nc * h, count=n_mamba)
            gemm("ssd_state", "ssm", s_.state_dim, s_.head_dim, q,
                 b=batch * nc * h, count=n_mamba)
            gemm("ssd_off", "ssm", q, s_.head_dim, s_.state_dim,
                 b=batch * nc * h, count=n_mamba)
    # output head
    from ..models.layers import pad_vocab
    gemm("lm_head", "head", pad_vocab(arch.vocab_size), t, d)
    return out


def nongemm_ops(arch: ArchConfig, batch: int, seq: int,
                dtype_bytes: int = 2) -> List[EwOp]:
    """Paper §3.2.3: the memory-bound phases with their flops/bytes."""
    t = batch * seq
    d = arch.d_model
    params = arch.param_count()
    nl = arch.num_layers
    acts = t * d * dtype_bytes
    n_attn = sum(1 for i in range(nl) if arch.is_attention_layer(i))
    # flops/bytes are PER KERNEL INSTANCE; count = kernel launches per step
    ops = [
        # LAMB stage 1 (fused per layer, as in PyTorch): read w,g,m,v + write
        # m,v,u in fp32 — the paper's "4x model size" traffic (Takeaway 8)
        EwOp("lamb_stage1", "lamb", flops=10 * params / nl,
             bytes=7 * 4 * params / nl, count=nl),
        # 2-norms + stage 2: read w,u + write w
        EwOp("lamb_stage2", "lamb", flops=3 * params / nl,
             bytes=3 * 4 * params / nl, count=nl),
    ]
    if arch.num_heads:
        # paper: scale, mask, softmax, dropout are 4 separate kernels per layer
        scores = batch * arch.num_heads * seq * seq
        ops.append(EwOp("attn_scale_mask_softmax", "attn_softmax",
                        flops=2 * scores, bytes=2 * scores * 4,
                        count=4 * n_attn))
    act_elems = t * (arch.d_ff or d)
    ops.append(EwOp("gelu" if arch.mlp == "gelu" else "swiglu_silu",
                    "activation", flops=8 * act_elems,
                    bytes=2 * act_elems * dtype_bytes, count=nl))
    ops.append(EwOp("dropout_residual_norm", "drn",
                    flops=t * d, bytes=2 * acts, count=6 * nl))
    ops.append(EwOp("loss_softmax", "loss",
                    flops=2 * t * arch.vocab_size,
                    bytes=2 * t * arch.vocab_size * 4, count=4))
    return ops


# --------------------------------------------------------- runtime estimation ----

def phase_times(arch: ArchConfig, batch: int, seq: int,
                dev: DeviceSpec = V5E, dtype_bytes: int = 2,
                train: bool = True) -> Dict[str, float]:
    """Roofline runtime per paper bucket (Fig 4/5 reproduction), single device.

    GEMM passes: fwd + bwd_act + bwd_w for training; EW ops scale 3x for
    fwd+bwd except LAMB (once per step) and loss.
    """
    times: Dict[str, float] = {}

    def add(bucket: str, secs: float):
        times[bucket] = times.get(bucket, 0.0) + secs

    phases = ("fwd", "bwd_act", "bwd_w") if train else ("fwd",)
    for phase in phases:
        for gm in transformer_gemms(arch, batch, seq, phase):
            t_c = gm.flops / dev.peak_flops
            t_m = gm.bytes_(dtype_bytes) / dev.hbm_bw
            add(gm.layer, max(t_c, t_m))
    for ew in nongemm_ops(arch, batch, seq, dtype_bytes):
        mult = 1
        if train and ew.layer in ("attn_softmax", "activation", "drn"):
            mult = 3                          # fwd + larger bwd (paper §3.2.3)
        if not train and ew.layer == "lamb":
            continue
        t_c = ew.total_flops / dev.peak_flops
        t_m = ew.total_bytes / (dev.hbm_bw * dev.ew_bw_efficiency)
        t_launch = ew.count * dev.kernel_overhead
        add(ew.layer, (max(t_c, t_m) + t_launch) * mult)
    return times


def total_flops(arch: ArchConfig, batch: int, seq: int,
                train: bool = True) -> float:
    phases = ("fwd", "bwd_act", "bwd_w") if train else ("fwd",)
    return sum(gm.flops for phase in phases
               for gm in transformer_gemms(arch, batch, seq, phase))
