"""Operator-level cost engine over compiled HLO text — the paper's methodology.

XLA's built-in ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so any
scanned-layer model under-reports FLOPs/bytes by the layer count. This engine walks
the compiled module's call graph, multiplies loop bodies by their
``known_trip_count``, prices every instruction (dot / fusion / reduce / collective /
data movement), and buckets costs by the paper's taxonomy AND by ``op_name`` metadata
(jax name_scopes), reproducing the paper's Fig 4/5-style runtime breakdowns from a
full-scale compiled artifact.

Pricing rules (per-device shapes — SPMD modules are per-device programs):
  dot         flops = 2 * prod(result) * prod(contracting dims); bytes = ops + out
  fusion      bytes = operands + result (internal traffic stays in registers/VMEM —
              the fusion benefit the paper measures); flops = elementwise body ops
  reduce      flops = input elements; bytes = in + out
  collectives bytes = operands (+ wire model in hlotext); no flops
  data mvmt   bytes = operands + result; no flops
  while       cost(body) * known_trip_count + cost(cond)
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from .hlotext import (CollectiveOp, CollectiveSummary, _COLL_KINDS,
                      _DTYPE_BYTES, _group_size, shape_bytes)

_TYPE_RE = re.compile(
    r"^(\((?:[^()]|\([^)]*\))*\)|\w+\[[^\]]*\](?:\{[^}]*\})?)\s*(.*)$")
_OP_RE = re.compile(r"^([\w\-]+)\(")
_SHAPE_ONLY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)\\?"')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(
    r"(?:(\w+\[[\d,]*\](?:\{[^}]*\})?)\s+)?%?([\w.\-]+)")

_EW_OPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "tanh", "negate", "abs", "sign",
    "log", "log-plus-one", "rsqrt", "sqrt", "cbrt", "sine", "cosine", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "logistic", "atan2",
    "compare", "select", "and", "or", "xor", "not", "clamp", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "erf",
}
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "domain",
    "opt-barrier", "get-dimension-size", "rng-get-and-update-state",
    # the CPU backend float-normalizes bf16 compute (bf16 -> f32 converts around
    # whole buffers, incl. scan carries); TPU executes bf16 natively, so converts
    # are priced as free — genuine cast traffic is captured by neighbors' bytes
    "convert",
}
_MOVE_OPS = {
    "copy", "copy-start", "copy-done", "transpose", "reshape", "broadcast",
    "iota", "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "pad", "concatenate", "slice", "reverse", "convert", "rng-bit-generator",
    "map", "reduce-window", "select-and-scatter", "real", "imag", "complex",
    "custom-call", "infeed", "outfeed", "rng",
}


def _shape_elems(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_ONLY_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str                               # everything after the op's '('
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]                  # param name -> type str
    instrs: List[Instr]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    by_category: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    by_category_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    by_scope: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    by_scope_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collectives: List[CollectiveOp] = dataclasses.field(default_factory=list)

    def add(self, other: "Cost", scale: float = 1.0) -> None:
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        for k, v in other.by_category.items():
            self.by_category[k] += v * scale
        for k, v in other.by_category_bytes.items():
            self.by_category_bytes[k] += v * scale
        for k, v in other.by_scope.items():
            self.by_scope[k] += v * scale
        for k, v in other.by_scope_bytes.items():
            self.by_scope_bytes[k] += v * scale
        for c in other.collectives:
            n = int(round(scale))
            self.collectives.extend([c] * max(n, 1))

    def summary(self) -> CollectiveSummary:
        return CollectiveSummary(self.collectives)


# ------------------------------------------------------------------- parsing ------

# greedy param capture: tuple params nest parens, and '->' appears exactly once
_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and line.rstrip().endswith("{"):
                name, params_str = m.group(1), m.group(2)
                params = {}
                for part in re.findall(r"([\w.\-]+)\s*:\s*"
                                       r"(\([^)]*\)|\w+\[[^\]]*\])", params_str):
                    params[part[0]] = part[1]
                cur = Computation(name=name, params=params, instrs=[])
                if line.lstrip().startswith("ENTRY"):
                    entry = name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        tm = _TYPE_RE.match(rhs)
        if not tm:
            continue
        type_str, rest = tm.groups()
        om = _OP_RE.match(rest.strip())
        if not om:
            continue
        cur.instrs.append(Instr(name=name, type_str=type_str,
                                op=om.group(1), rest=rest, line=line))
    if entry is None:
        # fall back: the computation containing the most instructions
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps else ""
    return comps, entry


# -------------------------------------------------------------------- pricing -----

def _categorize(op: str, rest: str) -> str:
    if op in ("dot", "convolution"):
        return "gemm"
    if op.replace("-start", "") in _COLL_KINDS:
        return "collective"
    if op in ("reduce",):
        return "reduction"
    if op == "fusion":
        return "fusion"
    if op == "sort":
        return "sort"
    if op in _MOVE_OPS:
        return "data_movement"
    if op in _EW_OPS:
        return "elementwise"
    return "other"


def _scope_of(line: str) -> str:
    m = _OPNAME_RE.search(line)
    if not m:
        return "unattributed"
    return m.group(1)


class Engine:
    def __init__(self, text: str, n_devices: int):
        self.comps, self.entry = parse_module(text)
        self.n_devices = n_devices
        self._cache: Dict[str, Cost] = {}

    # -- per-computation def table ------------------------------------------------
    def _types(self, comp: Computation) -> Dict[str, str]:
        table = dict(comp.params)
        for ins in comp.instrs:
            table[ins.name] = ins.type_str
        return table

    def _operand_bytes(self, ins: Instr, table: Dict[str, str]) -> int:
        return sum(shape_bytes(s) for s in
                   self._operand_shapes(ins, table) if s)

    def _operand_shapes(self, ins: Instr, table: Dict[str, str]) -> List[str]:
        m = re.match(rf"{re.escape(ins.op)}\(([^)]*)\)", ins.rest.strip())
        if not m:
            return []
        # Operand lists come in two dialects: bare names ("%a.1, %b.2") and
        # typed ("f32[64,128]{1,0} %a.1, ..."). A plain comma split breaks on
        # the commas inside typed shapes, so tokenize instead; when the type
        # is inline, use it directly rather than the name table.
        shapes: List[str] = []
        for typ, name in _OPERAND_RE.findall(m.group(1)):
            shapes.append(typ if typ else table.get(name, ""))
        return shapes

    # -- fusion body flops ----------------------------------------------------------
    def _fusion_flops(self, comp_name: str) -> float:
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        flops = 0.0
        for ins in comp.instrs:
            if ins.op in _EW_OPS:
                flops += _shape_elems(ins.type_str)
            elif ins.op == "reduce":
                shapes = self._operand_shapes(ins, self._types(comp))
                flops += _shape_elems(shapes[0]) if shapes else 0
            elif ins.op == "dot":
                flops += self._dot_flops(ins, self._types(comp))
            elif ins.op == "fusion":
                m = _CALLS_RE.search(ins.rest)
                if m:
                    flops += self._fusion_flops(m.group(1))
        return flops

    def _fusion_inplace_bytes(self, comp_name: str) -> float:
        """In-place-aware byte estimate for a fusion body.

        Scan machinery wraps cache slicing/updates in fusions whose *operands*
        are entire stacked buffers; XLA aliases those in place. Pricing each
        internal op by what actually moves (windows for DS/DUS, results for EW)
        and taking min() against the standard operands+result estimate keeps
        both plain EW fusions and slicing fusions honest.
        """
        comp = self.comps.get(comp_name)
        if comp is None:
            return float("inf")
        table = self._types(comp)
        total = 0.0
        for ins in comp.instrs:
            if ins.op in _FREE_OPS or ins.op == "iota":
                continue
            if ins.op == "dynamic-update-slice":
                shapes = self._operand_shapes(ins, table)
                total += 2 * (shape_bytes(shapes[1]) if len(shapes) > 1 else 0)
            elif ins.op in ("dynamic-slice", "slice", "gather"):
                total += 2 * shape_bytes(ins.type_str)
            elif ins.op == "scatter":
                shapes = self._operand_shapes(ins, table)
                total += 2 * (shape_bytes(shapes[2]) if len(shapes) > 2 else 0)
            elif ins.op == "fusion":
                m = _CALLS_RE.search(ins.rest)
                if m:
                    total += self._fusion_inplace_bytes(m.group(1))
            else:
                total += shape_bytes(ins.type_str)
        return total

    def _fusion_scope(self, comp_name: str) -> str:
        """Fallback scope for fusions: first op_name inside the fused body."""
        comp = self.comps.get(comp_name)
        if comp is None:
            return "unattributed"
        for ins in comp.instrs:
            m = _OPNAME_RE.search(ins.line)
            if m:
                return m.group(1)
        return "unattributed"

    def _dot_flops(self, ins: Instr, table: Dict[str, str]) -> float:
        out_elems = _shape_elems(ins.type_str)
        shapes = self._operand_shapes(ins, table)
        contract = 1
        m = _CONTRACT_RE.search(ins.rest)
        if m and shapes and shapes[0]:
            dims_str = _SHAPE_ONLY_RE.findall(shapes[0])
            if dims_str:
                lhs_dims = [int(d) for d in dims_str[0][1].split(",") if d]
                for ci in m.group(1).split(","):
                    if ci != "" and int(ci) < len(lhs_dims):
                        contract *= lhs_dims[int(ci)]
        return 2.0 * out_elems * contract

    # -- main recursion ------------------------------------------------------------
    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._cache:
            return self._cache[comp_name]
        comp = self.comps.get(comp_name)
        cost = Cost()
        if comp is None:
            self._cache[comp_name] = cost
            return cost
        table = self._types(comp)
        for ins in comp.instrs:
            cat = _categorize(ins.op, ins.rest)
            scope = _scope_of(ins.line)
            if scope == "unattributed" and ins.op == "fusion":
                m = _CALLS_RE.search(ins.rest)
                if m:
                    scope = self._fusion_scope(m.group(1))
            f = b = 0.0
            if ins.op == "while":
                body = _BODY_RE.search(ins.rest)
                cond = _COND_RE.search(ins.rest)
                trips = 1
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    trips = int(tm.group(1))
                if body:
                    cost.add(self.cost_of(body.group(1)), scale=trips)
                if cond:
                    cost.add(self.cost_of(cond.group(1)), scale=trips)
                continue
            if ins.op in ("call", "async-start"):
                m = _CALLS_RE.search(ins.rest) or re.search(
                    r"to_apply=%?([\w.\-]+)", ins.rest)
                if m:
                    cost.add(self.cost_of(m.group(1)))
                continue
            if ins.op == "conditional":
                for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                     r"(?:true|false)_computation=%?([\w.\-]+))",
                                     ins.rest):
                    names = (m.group(1) or m.group(2) or "").replace("%", "")
                    for nm in names.split(","):
                        nm = nm.strip()
                        if nm:
                            cost.add(self.cost_of(nm))
                continue
            if ins.op in _FREE_OPS:
                continue
            kind = ins.op.replace("-start", "")
            if kind in _COLL_KINDS:
                if ins.op.endswith("-done"):
                    continue
                rb = shape_bytes(ins.type_str)
                ob = self._operand_bytes(ins, table) or rb
                g, crosses = _group_size(ins.line, self.n_devices)
                cost.collectives.append(CollectiveOp(
                    kind=kind, result_bytes=rb, operand_bytes=ob,
                    group_size=g, crosses_pod=crosses, name=ins.name))
                b = ob
            elif ins.op == "fusion":
                b = self._operand_bytes(ins, table) + shape_bytes(ins.type_str)
                m = _CALLS_RE.search(ins.rest)
                if m:
                    b = min(b, self._fusion_inplace_bytes(m.group(1)))
                f = self._fusion_flops(m.group(1)) if m else 0.0
                # fusions that wrap a dot are GEMMs for taxonomy purposes
                if m and any(i.op == "dot" for i in
                             self.comps.get(m.group(1), Computation("", {}, [])
                                            ).instrs):
                    cat = "gemm"
            elif ins.op == "dot":
                f = self._dot_flops(ins, table)
                b = self._operand_bytes(ins, table) + shape_bytes(ins.type_str)
            elif ins.op == "reduce":
                shapes = self._operand_shapes(ins, table)
                f = float(_shape_elems(shapes[0])) if shapes else 0.0
                b = self._operand_bytes(ins, table) + shape_bytes(ins.type_str)
            elif ins.op in _EW_OPS:
                f = float(_shape_elems(ins.type_str))
                b = self._operand_bytes(ins, table) + shape_bytes(ins.type_str)
            elif ins.op == "dynamic-update-slice":
                # in-place semantics on TPU: only the update window moves
                shapes = self._operand_shapes(ins, table)
                upd = shape_bytes(shapes[1]) if len(shapes) > 1 else 0
                b = 2 * upd
            elif ins.op in ("dynamic-slice", "slice", "gather"):
                # read the window, write the result — not the whole operand
                b = 2 * shape_bytes(ins.type_str)
            elif ins.op == "scatter":
                shapes = self._operand_shapes(ins, table)
                b = 2 * (shape_bytes(shapes[2]) if len(shapes) > 2
                         else shape_bytes(ins.type_str))
            elif ins.op in ("copy", "copy-start"):
                # loop double-buffer copies are aliased on TPU; count one pass
                b = shape_bytes(ins.type_str)
            else:  # data movement & misc
                b = self._operand_bytes(ins, table) + shape_bytes(ins.type_str)
            cost.flops += f
            cost.bytes += b
            cost.by_category[cat] += f
            cost.by_category_bytes[cat] += b
            cost.by_scope[scope] += f
            cost.by_scope_bytes[scope] += b
        self._cache[comp_name] = cost
        return cost


def analyze_text(text: str, n_devices: int) -> Cost:
    eng = Engine(text, n_devices)
    return eng.cost_of(eng.entry)


# ------------------------------------------------------- scope bucketing ----------

_SCOPE_BUCKETS = (
    ("lamb", re.compile(r"lamb|optimizer|adamw|sgd", re.I)),
    ("attn_linear", re.compile(r"attn_qkv|attn_out|qkv_project", re.I)),
    ("attn_bgemm", re.compile(r"attn_core|attn_softmax", re.I)),
    ("moe", re.compile(r"moe", re.I)),
    ("mlp", re.compile(r"mlp|gelu|swiglu", re.I)),
    ("ssm", re.compile(r"mamba|ssd", re.I)),
    ("norm", re.compile(r"norm|ln", re.I)),
    ("embed_or_head", re.compile(r"embed|logits|unembed|head", re.I)),
    ("loss", re.compile(r"loss|cross_entropy|softmax_xent", re.I)),
)


def bucket_scopes(by_scope: Dict[str, float]) -> Dict[str, float]:
    """Fold fine-grained op_name scopes into paper-style buckets (Fig 4/5)."""
    out: Dict[str, float] = defaultdict(float)
    for scope, v in by_scope.items():
        for bucket, pat in _SCOPE_BUCKETS:
            if pat.search(scope):
                out[bucket] += v
                break
        else:
            out["other"] += v
    return dict(out)
