"""Block definitions and layer stacks.

Layers are stacked by *period*: the smallest repeating group of layers.
  dense/ssm/deepseek-moe : period 1
  llama4 (interleaved)   : period 2 (dense MLP, then MoE)
  jamba                  : period 8 (mamba x4, attn@4, mamba x3; MoE on odd layers)
Stacked parameters have a leading [num_periods, ...] axis and are consumed by
``jax.lax.scan`` (compile-time: one period lowered once — essential for 88-layer
models on the 512-device dry-run). ``arch.remat`` wraps the period body in
``jax.checkpoint`` so live activations are one [B, S, D] residual per period.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels.fused_layernorm import ops as ln_ops
from ..parallel.sharding import constrain
from . import attention as attn_lib
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import PyTree, apply_mlp, apply_norm, init_mlp, init_norm

REMAT_POLICY = jax.checkpoint_policies.nothing_saveable


# --------------------------------------------------------------------- periods ----

def period_length(arch: ArchConfig) -> int:
    if arch.family == "hybrid":
        return arch.hybrid_period
    if arch.moe is not None and arch.moe.every > 1:
        return arch.moe.every
    return 1


def layer_kinds(arch: ArchConfig) -> Tuple[Tuple[str, bool], ...]:
    """Per layer within one period: (mixer kind, has_moe)."""
    out = []
    for i in range(period_length(arch)):
        mixer = "attn" if arch.is_attention_layer(i) else "mamba"
        out.append((mixer, arch.is_moe_layer(i)))
    return tuple(out)


# ------------------------------------------------------------------------- init ---

def init_block(key, arch: ArchConfig, mixer: str, has_moe: bool,
               fuse_qkv: bool, dtype, cross: bool = False) -> PyTree:
    ks = jax.random.split(key, 4)
    p: PyTree = {"ln1": init_norm(arch.norm, arch.d_model, dtype)}
    if mixer == "attn":
        p["attn"] = attn_lib.init_attention(ks[0], arch, fuse_qkv, dtype=dtype)
    else:
        p["mamba"] = ssm_lib.init_mamba(ks[0], arch, dtype)
    if cross:
        p["ln_x"] = init_norm(arch.norm, arch.d_model, dtype)
        p["xattn"] = attn_lib.init_attention(ks[2], arch, fuse_qkv=False,
                                             cross=True, dtype=dtype)
    if arch.family == "ssm":
        return p  # mamba2 blocks have no MLP
    p["ln2"] = init_norm(arch.norm, arch.d_model, dtype)
    if has_moe:
        p["moe"] = moe_lib.init_moe(ks[1], arch, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], arch.mlp, arch.d_model, arch.d_ff,
                            arch.use_bias, dtype)
    return p


def init_period(key, arch: ArchConfig, fuse_qkv: bool, dtype,
                cross: bool = False) -> PyTree:
    kinds = layer_kinds(arch)
    ks = jax.random.split(key, len(kinds))
    return {f"layer_{i}": init_block(ks[i], arch, mixer, has_moe, fuse_qkv,
                                     dtype, cross)
            for i, (mixer, has_moe) in enumerate(kinds)}


def init_stack(key, arch: ArchConfig, fuse_qkv: bool, dtype,
               num_layers: Optional[int] = None, cross: bool = False) -> PyTree:
    plen = period_length(arch) if not cross else 1
    nl = num_layers if num_layers is not None else arch.num_layers
    assert nl % plen == 0, (arch.name, nl, plen)
    nper = nl // plen
    keys = jax.random.split(key, nper)
    if arch.scan_layers and nper > 1:
        return jax.vmap(
            lambda k: init_period(k, arch, fuse_qkv, dtype, cross))(keys)
    return {f"period_{z}": init_period(keys[z], arch, fuse_qkv, dtype, cross)
            for z in range(nper)}


# ------------------------------------------------------------------ block apply ---

def fused_blocks_enabled() -> bool:
    """Training/prefill block fusion (``fused_residual_layernorm`` +
    ``bias_gelu``) — default OFF. Unlike fused *decode* this is a
    tolerance-parity path, not a bit-parity one: the residual+norm kernel
    adds in fp32 where the unfused block adds in model dtype, so bf16
    training losses match to rounding, not bitwise."""
    return os.environ.get("REPRO_FUSED_BLOCKS", "0") == "1"


def apply_block(arch: ArchConfig, p: PyTree, x: jax.Array, mixer: str,
                positions: jax.Array, causal: bool, mrope_positions=None,
                enc_out=None,
                fused: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """Pre-norm (or BERT post-norm) residual block. Returns (y, aux_loss).

    ``fused`` (None = read ``REPRO_FUSED_BLOCKS``, default off) routes the
    residual-add + norm pairs through ``kernels.fused_layernorm`` — the
    post-norm sites through ``fused_residual_layernorm`` (the Fig-13 BERT
    pattern: add + stats + normalize in one VMEM pass), the pre-norm
    mixer-add + ln2 pair through ``decode_residual_norm`` — and the gelu
    MLP's bias+activation through ``kernels.bias_gelu``. Tolerance parity
    with the unfused block (``tests/test_kernels.py`` pins it); training
    and chunked prefill only — the decode path has its own bit-exact
    fusion (``paged_decode_period``)."""
    if fused is None:
        fused = fused_blocks_enabled()
    aux = jnp.zeros((), jnp.float32)
    rms = arch.norm == "rmsnorm"

    def mix(h):
        if mixer == "attn":
            return attn_lib.apply_attention(arch, p["attn"], h, positions,
                                            causal=causal,
                                            mrope_positions=mrope_positions)
        return ssm_lib.apply_mamba(arch, p["mamba"], h)

    # pre-norm ln2 can absorb the mixer's residual add; not when the block
    # has a cross-attention insert between the two sites, and not for ssm
    # blocks (no ln2 exists)
    fuse_pre_ln2 = (fused and not arch.post_norm and arch.family != "ssm"
                    and not (enc_out is not None and "xattn" in p))
    h = None
    if arch.post_norm:
        y = mix(x)
        if fused:
            x = ln_ops.fused_residual_layernorm(
                y, x, p["ln1"]["scale"], p["ln1"].get("bias"), rms=rms)
        else:
            x = apply_norm(arch.norm, p["ln1"], x + y)
    elif fuse_pre_ln2:
        y = mix(apply_norm(arch.norm, p["ln1"], x))
        h, x = ln_ops.decode_residual_norm(
            y, x, p["ln2"]["scale"], p["ln2"].get("bias"), kind=arch.norm)
    else:
        x = x + mix(apply_norm(arch.norm, p["ln1"], x))

    if enc_out is not None and "xattn" in p:
        h = apply_norm(arch.norm, p["ln_x"], x)
        enc_kv = attn_lib.project_enc_kv(arch, p["xattn"], enc_out)
        x = x + attn_lib.apply_cross_attention(arch, p["xattn"], h, enc_kv)
        h = None

    if arch.family == "ssm":
        return x, aux

    if arch.post_norm:
        if "moe" in p:
            y, aux = moe_lib.apply_moe(arch, p["moe"], x)
        else:
            y = apply_mlp(arch.mlp, p["mlp"], x, fused=fused)
        if fused:
            x = ln_ops.fused_residual_layernorm(
                y, x, p["ln2"]["scale"], p["ln2"].get("bias"), rms=rms)
        else:
            x = apply_norm(arch.norm, p["ln2"], x + y)
    else:
        if h is None:
            h = apply_norm(arch.norm, p["ln2"], x)
        if "moe" in p:
            y, aux = moe_lib.apply_moe(arch, p["moe"], h)
        else:
            y = apply_mlp(arch.mlp, p["mlp"], h, fused=fused)
        x = x + y
    return x, aux


def apply_period(arch: ArchConfig, p: PyTree, x: jax.Array,
                 positions: jax.Array, causal: bool, mrope_positions=None,
                 enc_out=None) -> Tuple[jax.Array, jax.Array]:
    aux_total = jnp.zeros((), jnp.float32)
    for i, (mixer, _) in enumerate(layer_kinds(arch)):
        # sequence-parallel residual stream between blocks (DESIGN.md §4)
        x = constrain(x, "batch", "seq", "embed")
        blk = functools.partial(apply_block, arch, mixer=mixer,
                                positions=positions, causal=causal,
                                mrope_positions=mrope_positions,
                                enc_out=enc_out)
        if arch.remat:
            # per-block remat: backward recomputes one block's internals at a
            # time; only the [B,S,D] residual per block stays live
            blk = jax.checkpoint(blk, policy=REMAT_POLICY)
        x, aux = blk(p[f"layer_{i}"], x)
        aux_total = aux_total + aux
    return constrain(x, "batch", "seq", "embed"), aux_total


# ----------------------------------------------------------------- stack apply ----

def apply_stack(arch: ArchConfig, stacked: PyTree, x: jax.Array,
                positions: jax.Array, causal: bool, mrope_positions=None,
                enc_out=None) -> Tuple[jax.Array, jax.Array]:
    body = functools.partial(apply_period, arch, positions=positions,
                             causal=causal, mrope_positions=mrope_positions,
                             enc_out=enc_out)

    if isinstance(stacked, dict) and any(k.startswith("period_") for k in stacked):
        aux_total = jnp.zeros((), jnp.float32)
        for z in range(len(stacked)):
            x, a = body(stacked[f"period_{z}"], x)
            aux_total = aux_total + a
        return x, aux_total

    def scan_body(carry, period_params):
        h, aux = carry
        h, a = body(period_params, h)
        return (h, aux + a), None
    (x, aux), _ = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


# ------------------------------------------------------------------ decode path ---

def init_caches(arch: ArchConfig, batch: int, max_len: int, dtype) -> PyTree:
    """Stacked per-period decode caches (incl. whisper cross-KV)."""
    def one_period():
        c: PyTree = {}
        for i, (mixer, _) in enumerate(layer_kinds(arch)):
            if mixer == "attn":
                c[f"layer_{i}"] = attn_lib.init_kv_cache(arch, batch, max_len, dtype)
                if arch.family == "encdec":
                    hd = arch.resolved_head_dim
                    c[f"layer_{i}"]["cross_k"] = jnp.zeros(
                        (batch, arch.enc_seq_len, arch.num_kv_heads, hd), dtype)
                    c[f"layer_{i}"]["cross_v"] = jnp.zeros(
                        (batch, arch.enc_seq_len, arch.num_kv_heads, hd), dtype)
            else:
                c[f"layer_{i}"] = ssm_lib.init_mamba_cache(arch, batch, dtype)
        return c
    nper = arch.num_layers // period_length(arch)
    per = one_period()
    if arch.scan_layers and nper > 1:
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (nper,) + l.shape).copy(), per)
    return {f"period_{z}": one_period() for z in range(nper)}


def init_serving_state(arch: ArchConfig, num_pages: int, page_size: int,
                       num_slots: int, dtype) -> PyTree:
    """Per-layer decode state for the continuous engine, stacked like
    ``init_caches`` — the decode-state protocol's device side.

    Each layer kind declares its own state:

    - ``attn``  : a paged KV pool ``{k, v}: [P, page, Hkv, Dh]``. Every
      attention layer shares one logical page table — a sequence's page ids
      index the same rows of every layer's pool, so the allocator hands out
      ids once and the whole stack follows (vLLM's layout).
    - ``mamba`` : a pooled, constant-size per-*slot* state
      ``{conv: [slot, W-1, C], state: [slot, H, N, P]}`` — the recurrence
      folds all history into fixed-size state, so it is allocated per decode
      slot, not per page, and costs nothing as context grows.
    """
    assert arch.family != "encdec", "paged path has no cross-attention cache"
    kinds = layer_kinds(arch)

    def layer_state(mixer):
        if mixer == "attn":
            return attn_lib.init_paged_kv_cache(arch, num_pages, page_size,
                                                dtype)
        return ssm_lib.init_mamba_cache(arch, num_slots, dtype)

    def one_period():
        return {f"layer_{i}": layer_state(m)
                for i, (m, _) in enumerate(kinds)}
    nper = arch.num_layers // period_length(arch)
    if arch.scan_layers and nper > 1:
        per = one_period()
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (nper,) + l.shape).copy(), per)
    return {f"period_{z}": one_period() for z in range(nper)}


def init_paged_caches(arch: ArchConfig, num_pages: int, page_size: int,
                      dtype) -> PyTree:
    """Attention-only page pools (the pre-protocol surface, kept for callers
    that size pure KV pools); mixed stacks go through ``init_serving_state``.
    """
    kinds = layer_kinds(arch)
    assert all(m == "attn" for m, _ in kinds), \
        f"paged caches need attention-only stacks, got {kinds} ({arch.name})"
    return init_serving_state(arch, num_pages, page_size, 0, dtype)


def _decode_block_mix(arch: ArchConfig, blk: PyTree, x: jax.Array, mix_fn
                      ) -> Tuple[jax.Array, PyTree]:
    """Shared pre/post-norm residual wrapping of a decode mixer.
    ``mix_fn(h) -> (y, new_cache)``."""
    h = x if arch.post_norm else apply_norm(arch.norm, blk["ln1"], x)
    y, new_c = mix_fn(h)
    x = apply_norm(arch.norm, blk["ln1"], x + y) if arch.post_norm else x + y
    return x, new_c


def _decode_block_ffn(arch: ArchConfig, blk: PyTree, x: jax.Array,
                      tp_axis: Optional[str] = None,
                      moe_eff_cap: Optional[jax.Array] = None) -> jax.Array:
    """Shared MoE/MLP tail of a decode block (no-op for mamba2 blocks).
    ``tp_axis``: serving tensor parallelism — the MLP runs on Megatron
    shards and psums its row-parallel output; a MoE block runs its
    expert-parallel path (experts sharded on the leading axis, one psum on
    the combine). ``moe_eff_cap`` (prefill chunks): the full prompt's
    capacity, so drops match the static engine's full-prompt dispatch
    rather than a bucket inflated by the chunk's padded shape."""
    if arch.family == "ssm":
        return x
    h = x if arch.post_norm else apply_norm(arch.norm, blk["ln2"], x)
    if "moe" in blk:
        y, _ = moe_lib.apply_moe(arch, blk["moe"], h, tp_axis, moe_eff_cap)
    else:
        y = apply_mlp(arch.mlp, blk["mlp"], h, tp_axis)
    return apply_norm(arch.norm, blk["ln2"], x + y) if arch.post_norm else x + y


def _fused_residual_norm(arch: ArchConfig, ln: PyTree, d: jax.Array,
                         x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Fold the pending residual delta ``d`` into the stream and norm it in
    one fused pass: ``x += d; h = norm(x)`` -> ``(h, x_new)``. Bit-identical
    to the unfused two-op sequence (see ``kernels.fused_layernorm.ref``)."""
    return ln_ops.decode_residual_norm(d, x, ln["scale"], ln.get("bias"),
                                       kind=arch.norm)


def _fused_block_delta(arch: ArchConfig, blk: PyTree, h: jax.Array,
                       tp_axis: Optional[str] = None,
                       moe_eff_cap: Optional[jax.Array] = None) -> jax.Array:
    """MoE/MLP tail of a fused decode block: returns the residual *delta*
    (the add is deferred into the next in-period layer's fused pre-norm, or
    into the period-end boundary add for the last layer)."""
    if "moe" in blk:
        y, _ = moe_lib.apply_moe(arch, blk["moe"], h, tp_axis, moe_eff_cap)
        return y
    return apply_mlp(arch.mlp, blk["mlp"], h, tp_axis)


def paged_decode_period(arch: ArchConfig, p: PyTree, cache: PyTree,
                        x: jax.Array, page_table: jax.Array,
                        seq_lens: jax.Array, mrope_positions=None,
                        tp_axis: Optional[str] = None,
                        fused: bool = False) -> Tuple[jax.Array, PyTree]:
    """One period of single-token decode. ``fused=True`` carries the
    residual stream through the period as an ``(x, pending-delta)`` pair:
    every residual-add + pre-norm pair collapses into one
    ``decode_residual_norm`` pass (the mixer add at each ln2 site; the
    previous layer's MLP delta at each ln1 site of a multi-layer period),
    so the residual stream makes one HBM round-trip per fused site instead
    of three (add-out, norm-read, delta-write). The pending delta is folded
    by a plain add before returning — the period's carry interface (and,
    bitwise, its result: the fused kernels duplicate the unfused op
    sequence exactly, and the boundary add sits at the same graph position
    as the unfused path's, which keeps XLA's context-sensitive fusion
    choices identical across the two variants) matches ``fused=False``.
    Pre-norm stacks only."""
    if fused:
        assert not arch.post_norm, (arch.name, "fused decode is pre-norm only")
    new_cache: PyTree = {}
    d: Optional[jax.Array] = None     # pending in-period residual delta
    # a slot with seq_len 0 is empty or mid-prefill: attention routes its
    # writes to the null page; mamba layers must instead keep their state row
    active = seq_lens > 0
    for i, (mixer, _) in enumerate(layer_kinds(arch)):
        x = constrain(x, "batch", None, None)
        blk = p[f"layer_{i}"]

        def mix(h, blk=blk, i=i, mixer=mixer):
            if mixer == "attn":
                return attn_lib.paged_decode_attention_layer(
                    arch, blk["attn"], h, cache[f"layer_{i}"], page_table,
                    seq_lens, mrope_positions, tp_axis)
            return ssm_lib.paged_decode_mamba_layer(
                arch, blk["mamba"], h, cache[f"layer_{i}"], active)
        if fused:
            if d is None:
                h = apply_norm(arch.norm, blk["ln1"], x)
            else:
                h, x = _fused_residual_norm(arch, blk["ln1"], d, x)
            y, new_cache[f"layer_{i}"] = mix(h)
            if arch.family == "ssm":
                d = y  # mamba2 blocks have no MLP: y is the pending delta
            else:
                h2, x = _fused_residual_norm(arch, blk["ln2"], y, x)
                d = _fused_block_delta(arch, blk, h2, tp_axis)
        else:
            x, new_cache[f"layer_{i}"] = _decode_block_mix(arch, blk, x, mix)
            x = _decode_block_ffn(arch, blk, x, tp_axis)
    if fused:
        x = x + d
    return x, new_cache


def paged_decode_stack(arch: ArchConfig, stacked: PyTree, caches: PyTree,
                       x: jax.Array, page_table: jax.Array,
                       seq_lens: jax.Array, mrope_positions=None,
                       tp_axis: Optional[str] = None,
                       fused: bool = False):
    """Single-token decode through the whole stack. ``fused=True`` runs the
    residual+norm-fused period bodies; the carry between periods is the
    plain completed residual either way (bit-identical to ``fused=False`` —
    the fused body keeps every residual add at the same graph position, so
    XLA's context-sensitive lowering of the norm reductions matches).
    Pre-norm stacks only."""
    if fused:
        assert not arch.post_norm, (arch.name, "fused decode is pre-norm only")
    if isinstance(stacked, dict) and any(k.startswith("period_") for k in stacked):
        new_caches: PyTree = {}
        for z in range(len(stacked)):
            x, nc = paged_decode_period(
                arch, stacked[f"period_{z}"], caches[f"period_{z}"],
                x, page_table, seq_lens, mrope_positions,
                tp_axis, fused=fused)
            new_caches[f"period_{z}"] = nc
        return x, new_caches

    def scan_body(h, inputs):
        period_params, cache = inputs
        h, new_cache = paged_decode_period(
            arch, period_params, cache, h, page_table,
            seq_lens, mrope_positions, tp_axis, fused=fused)
        return h, new_cache
    x, new_caches = jax.lax.scan(scan_body, x, (stacked, caches))
    return x, new_caches


# ---- multi-step compiled decode -------------------------------------------
# Per-slot exit-reason bits returned by `paged_decode_loop`. A dispatch that
# ran the full horizon with no bit set exited on the N-step horizon alone.
EXIT_EOS = 1        # slot emitted its request's eos token
EXIT_BUDGET = 2     # slot emitted its last allowed token (max-new / context)
EXIT_PAGES = 4      # slot's next K/V write would fall past its allocated pages


def paged_decode_loop(arch: ArchConfig, stacked: PyTree, caches: PyTree,
                      tokens: jax.Array, page_table: jax.Array,
                      seq_lens: jax.Array, active: jax.Array,
                      budget: jax.Array, page_limit: jax.Array,
                      eos_ids: jax.Array, *, horizon: int, embed, unembed,
                      select, probe: bool = False,
                      tp_axis: Optional[str] = None, fused_head=None):
    """Up to ``horizon`` decode iterations in one on-device ``lax.while_loop``.

    The loop body is exactly one single-step decode (``paged_decode_stack``
    + LM head + the caller's token selection) with the carry advanced the
    way the host would have between dispatches: ``seq_lens`` increments for
    active slots each iteration, and the sampling position handed to
    ``select`` is the carried ``seq_lens + 1`` — so the (seed, position)
    PRNG key of every draw matches the single-step engine bit-for-bit at
    any horizon, including across forced-replay preemption.

    Carry: ``(i, tokens, seq_lens, caches, emitted buffer [horizon, S],
    exit-reason bits [S], finite-probe ok)``. The loop exits as soon as ANY
    slot records an exit event, so events can only be set on the final
    executed iteration and every one of the ``i`` returned iterations is
    valid for every active slot — the host appends exactly ``i`` tokens per
    slot and never sees a token past a slot's EOS.

    Exit predicates (the in-loop restatement of the host scheduler's
    per-token decisions):

    - ``EXIT_EOS``:    the token just emitted equals the slot's ``eos_ids``
                       entry (-1 for requests without one — never matches).
    - ``EXIT_BUDGET``: the slot emitted its last allowed token
                       (``budget[s]`` = host-computed remaining max-new /
                       context-capacity allowance).
    - ``EXIT_PAGES``:  checked *before* an iteration — an active slot's
                       next K/V write position (= its carried ``seq_lens``)
                       would land past ``page_limit[s]`` (allocated pages ×
                       page size). Computed again after the loop so the
                       host sees which slot needs a page, not just that the
                       loop stopped.

    Returns ``(buf [horizon, S], steps, reasons [S], caches[, ok])`` with
    ``steps >= 1`` (the host guarantees iteration 0's predicates hold).
    ``embed``/``unembed`` are the model's token embedding / LM head;
    ``select(logits [S, V], positions [S]) -> int32 [S]`` picks tokens
    (argmax or the fused-sampling epilogue) from the in-carry positions.
    ``fused_head(x, positions) -> (tokens [S], ok [S])`` replaces
    ``unembed`` + ``select`` on the fused-decode path: the final hidden
    state from ``paged_decode_stack(fused=True)`` goes straight into the
    streaming final-norm + LM-head epilogue, no [S, V] logits buffer ever
    exists, and the finite probe rides out of the epilogue's in-register
    sweep instead of scanning materialized logits.
    Inactive slots (mid-prefill or empty, masked to the null page) never
    advance ``seq_lens``, never set exit bits, and their junk draws are
    discarded by the host.
    """
    n_slots = tokens.shape[0]

    def _cond(carry):
        i, _tok, lens, _caches, _buf, reasons, _ok = carry
        blocked = active & (lens >= page_limit)
        return (i < horizon) & jnp.all(reasons == 0) & ~jnp.any(blocked)

    def _body(carry):
        i, tok, lens, caches, buf, reasons, ok = carry
        x = embed(tok[:, None])
        if fused_head is not None:
            x, caches = paged_decode_stack(
                arch, stacked, caches, x, page_table, lens, tp_axis=tp_axis,
                fused=True)
            new, ok_rows = fused_head(x, lens + 1)
            if probe:
                # row-wise finite probe from the epilogue's streaming sweep
                # — boolean-identical to scanning the full logits row
                ok = ok & jnp.all(ok_rows | ~active)
        else:
            x, caches = paged_decode_stack(arch, stacked, caches, x,
                                           page_table, lens, tp_axis=tp_axis)
            logits = unembed(x)
            new = select(logits, lens + 1)
            if probe:
                # inactive slots read the null page and may legitimately
                # produce junk — probe only the live rows
                ok = ok & jnp.all(jnp.isfinite(logits) | ~active[:, None])
        buf = buf.at[i].set(new)
        reasons = reasons \
            | jnp.where(active & (new == eos_ids), EXIT_EOS, 0) \
            | jnp.where(active & (i + 1 >= budget), EXIT_BUDGET, 0)
        lens = lens + active.astype(lens.dtype)
        return (i + 1, new, lens, caches, buf, reasons, ok)

    carry = (jnp.zeros((), jnp.int32), tokens, seq_lens, caches,
             jnp.zeros((horizon, n_slots), jnp.int32),
             jnp.zeros((n_slots,), jnp.int32), jnp.asarray(True))
    steps, _tok, lens, caches, buf, reasons, ok = jax.lax.while_loop(
        _cond, _body, carry)
    reasons = reasons | jnp.where(active & (lens >= page_limit),
                                  EXIT_PAGES, 0)
    if probe:
        return buf, steps, reasons, caches, ok
    return buf, steps, reasons, caches


def paged_prefill_period(arch: ArchConfig, p: PyTree, cache: PyTree,
                         x: jax.Array, page_row: jax.Array, start: jax.Array,
                         total_len: jax.Array, slot: jax.Array,
                         moe_cap: Optional[jax.Array] = None,
                         mrope_positions=None,
                         tp_axis: Optional[str] = None,
                         fused: bool = False) -> Tuple[jax.Array, PyTree]:
    if fused:
        assert not arch.post_norm, (arch.name, "fused prefill is pre-norm only")
    new_cache: PyTree = {}
    d: Optional[jax.Array] = None     # pending in-period residual delta
    # MoE capacity for a prompt chunk: the FULL context's bucket (computed
    # host-side by the engine with the same math as the static path), not
    # the padded chunk shape's. The trailing padding itself is harmless —
    # the stable expert sort keeps padded entries behind every real token —
    # but the chunk shape would otherwise inflate the drop threshold away
    # from the static engine's, so a prompt that fits one chunk drops
    # exactly what a full-prompt dispatch would. Longer prompts still
    # re-bucket per chunk (documented caveat).
    moe_eff_cap = moe_cap if arch.moe is not None else None
    for i, (mixer, _) in enumerate(layer_kinds(arch)):
        x = constrain(x, "batch", None, None)
        blk = p[f"layer_{i}"]

        def mix(h, blk=blk, i=i, mixer=mixer):
            if mixer == "attn":
                return attn_lib.paged_prefill_attention_layer(
                    arch, blk["attn"], h, cache[f"layer_{i}"], page_row,
                    start, total_len, mrope_positions, tp_axis)
            return ssm_lib.paged_prefill_mamba_layer(
                arch, blk["mamba"], h, cache[f"layer_{i}"], slot, start,
                total_len)
        if fused:
            if d is None:
                h = apply_norm(arch.norm, blk["ln1"], x)
            else:
                h, x = _fused_residual_norm(arch, blk["ln1"], d, x)
            y, new_cache[f"layer_{i}"] = mix(h)
            if arch.family == "ssm":
                d = y
            else:
                h2, x = _fused_residual_norm(arch, blk["ln2"], y, x)
                d = _fused_block_delta(arch, blk, h2, tp_axis, moe_eff_cap)
        else:
            x, new_cache[f"layer_{i}"] = _decode_block_mix(arch, blk, x, mix)
            x = _decode_block_ffn(arch, blk, x, tp_axis,
                                  moe_eff_cap=moe_eff_cap)
    if fused:
        x = x + d
    return x, new_cache


def chunk_final_hidden(x: jax.Array, start: jax.Array,
                       total_len: jax.Array) -> jax.Array:
    """[B, C, D] chunk activations -> [B, 1, D] hidden state of the chunk's
    last *valid* token (position ``total_len - 1``; the chunk is padded past
    it). This is the logits surface for the final prefill chunk: the LM head
    + sampler run on exactly this one position — earlier chunks exist only
    to fill KV pages and never pay the head."""
    return jax.lax.dynamic_slice_in_dim(x, total_len - 1 - start, 1, axis=1)


def paged_prefill_stack(arch: ArchConfig, stacked: PyTree, caches: PyTree,
                        x: jax.Array, page_row: jax.Array, start: jax.Array,
                        total_len: jax.Array, slot: jax.Array = None,
                        moe_cap: Optional[jax.Array] = None,
                        mrope_positions=None,
                        tp_axis: Optional[str] = None,
                        fused: bool = False):
    """Chunked prefill: one prompt chunk [1, C, D] of one sequence through
    the stack — attention K/V written straight into the sequence's pages,
    mamba state advanced in the sequence's slot row (``slot``; only needed
    for SSM-bearing stacks), MoE layers dropping at the full context's
    capacity (``moe_cap``, host-computed; only read for MoE-bearing
    stacks). The caller slices the sampling position out of the returned
    activations with ``chunk_final_hidden``. ``fused=True`` mirrors
    ``paged_decode_stack``: residual+norm-fused period bodies, plain
    completed residual as the carry, bit-identical to ``fused=False``."""
    if slot is None:
        slot = jnp.zeros((), jnp.int32)
    if fused:
        assert not arch.post_norm, (arch.name, "fused prefill is pre-norm only")
    if isinstance(stacked, dict) and any(k.startswith("period_") for k in stacked):
        new_caches: PyTree = {}
        for z in range(len(stacked)):
            x, nc = paged_prefill_period(
                arch, stacked[f"period_{z}"], caches[f"period_{z}"],
                x, page_row, start, total_len, slot,
                moe_cap, mrope_positions, tp_axis, fused=fused)
            new_caches[f"period_{z}"] = nc
        return x, new_caches

    def scan_body(h, inputs):
        period_params, cache = inputs
        h, new_cache = paged_prefill_period(
            arch, period_params, cache, h, page_row,
            start, total_len, slot, moe_cap, mrope_positions, tp_axis,
            fused=fused)
        return h, new_cache
    x, new_caches = jax.lax.scan(scan_body, x, (stacked, caches))
    return x, new_caches


def decode_period(arch: ArchConfig, p: PyTree, cache: PyTree, x: jax.Array,
                  positions: jax.Array, mrope_positions=None
                  ) -> Tuple[jax.Array, PyTree]:
    new_cache: PyTree = {}
    for i, (mixer, _) in enumerate(layer_kinds(arch)):
        x = constrain(x, "batch", None, None)
        blk = p[f"layer_{i}"]
        layer_cache = cache[f"layer_{i}"]

        def mix(h, blk=blk, layer_cache=layer_cache, mixer=mixer):
            if mixer == "attn":
                kv_cache = {"k": layer_cache["k"], "v": layer_cache["v"]}
                y, new_kv = attn_lib.extend_attention(
                    arch, blk["attn"], h, kv_cache, positions, mrope_positions)
                new_c = dict(layer_cache)
                new_c.update(new_kv)
                return y, new_c
            return ssm_lib.extend_mamba(arch, blk["mamba"], h, layer_cache)
        x, new_cache[f"layer_{i}"] = _decode_block_mix(arch, blk, x, mix)

        if "xattn" in blk:
            h = apply_norm(arch.norm, blk["ln_x"], x)
            enc_kv = (layer_cache["cross_k"], layer_cache["cross_v"])
            x = x + attn_lib.apply_cross_attention(arch, blk["xattn"], h, enc_kv)

        x = _decode_block_ffn(arch, blk, x)
    return x, new_cache


def decode_stack(arch: ArchConfig, stacked: PyTree, caches: PyTree, x: jax.Array,
                 positions: jax.Array, mrope_positions=None
                 ) -> Tuple[jax.Array, PyTree]:
    if isinstance(stacked, dict) and any(k.startswith("period_") for k in stacked):
        new_caches: PyTree = {}
        for z in range(len(stacked)):
            x, nc = decode_period(arch, stacked[f"period_{z}"],
                                  caches[f"period_{z}"], x, positions,
                                  mrope_positions)
            new_caches[f"period_{z}"] = nc
        return x, new_caches

    def scan_body(h, inputs):
        period_params, cache = inputs
        h, new_cache = decode_period(arch, period_params, cache, h,
                                     positions, mrope_positions)
        return h, new_cache
    x, new_caches = jax.lax.scan(scan_body, x, (stacked, caches))
    return x, new_caches
