"""GQA attention: fused/serial QKV projections, naive + chunked (online-softmax)
implementations, KV-cache decode path, RoPE / M-RoPE, optional sliding window.

The paper's Fig 14/15 "GEMM fusion" optimization is the ``fuse_qkv`` init/apply
option: one [D, (Hq+2*Hkv)*Dh] GEMM instead of three. The paper's memory-bound
"attention B-GEMM + scale/mask/softmax" ops (§3.2.3) are what the chunked/flash
implementations restructure for TPU: no [S, S] score tensor is ever resident in HBM —
the online-softmax recurrence keeps a [Sq, chunk] tile in VMEM (Pallas kernel in
``repro.kernels.flash_attention``; the pure-JAX chunked path here is its oracle and
the CPU-lowerable stand-in used by the dry-run).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..parallel.sharding import constrain
from .layers import PyTree, apply_mrope, apply_rope, dense, dense_init

NEG_INF = -1e30


# ------------------------------------------------------------------------- init ---

def init_attention(key, arch: ArchConfig, fuse_qkv: bool = True,
                   cross: bool = False, dtype=jnp.float32) -> PyTree:
    d, hd = arch.d_model, arch.resolved_head_dim
    qd, kvd = arch.q_dim, arch.kv_dim
    ks = jax.random.split(key, 4)
    p: PyTree = {}
    if fuse_qkv and not cross:
        p["wqkv"] = dense_init(ks[0], d, qd + 2 * kvd, dtype)
        if arch.use_bias:
            p["bqkv"] = jnp.zeros((qd + 2 * kvd,), dtype)
    else:
        p["wq"] = dense_init(ks[0], d, qd, dtype)
        p["wk"] = dense_init(ks[1], d, kvd, dtype)
        p["wv"] = dense_init(ks[2], d, kvd, dtype)
        if arch.use_bias:
            p["bq"] = jnp.zeros((qd,), dtype)
            p["bk"] = jnp.zeros((kvd,), dtype)
            p["bv"] = jnp.zeros((kvd,), dtype)
    p["wo"] = dense_init(ks[3], qd, d, dtype)
    if arch.use_bias:
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def qkv_project(arch: ArchConfig, p: PyTree, x: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """-> q [B,S,Hq,Dh], k/v [B,S,Hkv,Dh].

    Head counts are inferred from the projection widths, not the arch: under
    the serving engine's tensor parallelism this runs inside shard_map on
    weight shards holding Hq/tp (resp. Hkv/tp) contiguous heads, and the
    reshape must follow the local width."""
    b, s, _ = x.shape
    hd = arch.resolved_head_dim
    if "wqkv" in p:
        qkv = dense(x, p["wqkv"], p.get("bqkv"))
        q, k, v = jnp.split(qkv, [arch.q_dim, arch.q_dim + arch.kv_dim], axis=-1)
    else:
        q = dense(x, p["wq"], p.get("bq"))
        k = dense(x, p["wk"], p.get("bk"))
        v = dense(x, p["wv"], p.get("bv"))
    q = q.reshape(b, s, q.shape[-1] // hd, hd)
    k = k.reshape(b, s, k.shape[-1] // hd, hd)
    v = v.reshape(b, s, v.shape[-1] // hd, hd)
    return q, k, v


def position_encode(arch: ArchConfig, q: jax.Array, k: jax.Array,
                    positions: jax.Array,
                    mrope_positions: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    if arch.pos_emb == "rope":
        q = apply_rope(q, positions, arch.rope_theta)
        k = apply_rope(k, positions, arch.rope_theta)
    elif arch.pos_emb == "mrope":
        if mrope_positions is None:
            # text-only fallback: t == h == w == position
            mrope_positions = jnp.broadcast_to(positions[None],
                                               (3,) + positions.shape)
        q = apply_mrope(q, mrope_positions, arch.rope_theta)
        k = apply_mrope(k, mrope_positions, arch.rope_theta)
    # learned / sinusoidal / none: applied at the embedding, nothing to do here
    return q, k


# ------------------------------------------------------------ core implementations

def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q [B,Sq,Hq,D], k [B,Sk,Hkv,D] -> scores [B,Hq,Sq,Sk] with GQA grouping."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k)
    return s.reshape(b, hq, sq, k.shape[1])


def _gqa_values(p: jax.Array, v: jax.Array) -> jax.Array:
    """p [B,Hq,Sq,Sk], v [B,Sk,Hkv,D] -> [B,Sq,Hq,D]."""
    b, hq, sq, sk = p.shape
    hkv = v.shape[2]
    g = hq // hkv
    pg = p.reshape(b, hkv, g, sq, sk)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pg, v)
    return o.reshape(b, sq, hq, v.shape[3])


def naive_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, q_offset: int = 0,
                    kv_len: Optional[jax.Array] = None,
                    window: int = 0) -> jax.Array:
    """Reference full-matrix attention (test/small shapes; the chunked oracle)."""
    d = q.shape[-1]
    s = _gqa_scores(q, k).astype(jnp.float32) / jnp.sqrt(d).astype(jnp.float32)
    sq, sk = s.shape[2], s.shape[3]
    rows = jnp.arange(sq)[:, None] + q_offset
    cols = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    if kv_len is not None:  # per-batch valid cache length: [B]
        valid = cols[None] < kv_len[:, None, None]          # [B,1,Sk]
        s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return _gqa_values(p, v)


def _chunk_mask(sq: int, chunk: int, j, *, causal: bool, q_offset: int,
                window: int, kv_len, scores: jax.Array) -> jax.Array:
    """Apply causal/window/cache-length masking to one [B,Hq,Sq,chunk] tile."""
    rows = jnp.arange(sq)[:, None] + q_offset
    cols = j * chunk + jnp.arange(chunk)[None, :]
    mask = jnp.ones((sq, chunk), bool)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= cols > rows - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    if kv_len is not None:
        valid = cols[None] < kv_len[:, None, None]
        scores = jnp.where(valid[:, None], scores, NEG_INF)
    return scores


def _chunked_fwd_impl(q, k, v, kv_len, causal, chunk, q_offset, window):
    b, sq, hq, d = q.shape
    nchunks = k.shape[1] // chunk
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    k_ch = k.reshape(b, nchunks, chunk, *k.shape[2:]).transpose(1, 0, 2, 3, 4)
    v_ch = v.reshape(b, nchunks, chunk, *v.shape[2:]).transpose(1, 0, 2, 3, 4)

    def body(carry, inputs):
        o, m, l = carry                                      # [B,Hq,Sq,D] fp32 acc
        j, kj, vj = inputs
        s = _gqa_scores(q, kj).astype(jnp.float32) * scale   # [B,Hq,Sq,chunk]
        s = _chunk_mask(sq, chunk, j, causal=causal, q_offset=q_offset,
                        window=window, kv_len=kv_len, scores=s)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))          # [B,Hq,Sq]
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])                    # [B,Hq,Sq,chunk]
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = _gqa_values(p.astype(q.dtype), vj)              # [B,Sq,Hq,D]
        pv = pv.transpose(0, 2, 1, 3).astype(jnp.float32)    # [B,Hq,Sq,D]
        o_new = o * alpha[..., None] + pv
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    (o, m, l), _ = jax.lax.scan(
        body, (o0, m0, l0), (jnp.arange(nchunks), k_ch, v_ch))
    l = jnp.maximum(l, 1e-30)
    out = (o / l[..., None]).transpose(0, 2, 1, 3).astype(q.dtype)
    lse = m + jnp.log(l)                                     # [B,Hq,Sq]
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _chunked_attn(q, k, v, kv_len, causal, chunk, q_offset, window):
    out, _ = _chunked_fwd_impl(q, k, v, kv_len, causal, chunk, q_offset, window)
    return out


def _chunked_attn_fwd(q, k, v, kv_len, causal, chunk, q_offset, window):
    out, lse = _chunked_fwd_impl(q, k, v, kv_len, causal, chunk, q_offset,
                                 window)
    return out, (q, k, v, kv_len, out, lse)


def _chunked_attn_bwd(causal, chunk, q_offset, window, res, do):
    """Flash-attention backward: recompute score tiles per chunk, never holding
    more than one [B,Hq,Sq,chunk] tile (the per-chunk saves of plain autodiff
    through the forward scan cost GBs/layer — see EXPERIMENTS.md §Perf)."""
    q, k, v, kv_len, out, lse = res
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    nchunks = k.shape[1] // chunk
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    k_ch = k.reshape(b, nchunks, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    v_ch = v.reshape(b, nchunks, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    do_g = do.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    out_f = out.astype(jnp.float32)
    delta = jnp.sum(do.astype(jnp.float32) * out_f, axis=-1)  # [B,Sq,Hq]
    delta = delta.transpose(0, 2, 1)                          # [B,Hq,Sq]
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)

    def body(dq_acc, inputs):
        j, kj, vj = inputs
        s = _gqa_scores(q, kj).astype(jnp.float32) * scale    # [B,Hq,Sq,C]
        s = _chunk_mask(sq, chunk, j, causal=causal, q_offset=q_offset,
                        window=window, kv_len=kv_len, scores=s)
        p = jnp.exp(s - lse[..., None])                       # [B,Hq,Sq,C]
        pg = p.reshape(b, hkv, g, sq, chunk)
        kjf = kj.astype(jnp.float32)
        vjf = vj.astype(jnp.float32)
        dv_j = jnp.einsum("bhgqc,bqhgd->bchd", pg, do_g)      # [B,C,Hkv,D]
        dp = jnp.einsum("bqhgd,bchd->bhgqc", do_g, vjf)
        ds = pg * (dp - delta.reshape(b, hkv, g, sq)[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bhgqc,bchd->bqhgd", ds, kjf)
        dk_j = jnp.einsum("bhgqc,bqhgd->bchd", ds, qf)
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((b, sq, hkv, g, d), jnp.float32)
    dq, (dk_ch, dv_ch) = jax.lax.scan(
        body, dq0, (jnp.arange(nchunks), k_ch, v_ch))
    dq = dq.reshape(b, sq, hq, d).astype(q.dtype)
    dk = dk_ch.transpose(1, 0, 2, 3, 4).reshape(b, nchunks * chunk, hkv, d)
    dv = dv_ch.transpose(1, 0, 2, 3, 4).reshape(b, nchunks * chunk, hkv, d)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype), None


_chunked_attn.defvjp(_chunked_attn_fwd, _chunked_attn_bwd)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, chunk: int, q_offset: int = 0,
                      kv_len: Optional[jax.Array] = None,
                      window: int = 0) -> jax.Array:
    """Online-softmax attention over KV chunks with a flash-style custom VJP.

    Never materializes [Sq, Sk] in either direction; peak live score tile is
    [B, Hq, Sq, chunk]. This is the lowerable stand-in (and the oracle) for the
    Pallas flash kernel.
    """
    b = q.shape[0]
    sk = k.shape[1]
    if sk % chunk != 0:
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        tail_len = jnp.full((b,), sk, jnp.int32)
        kv_len = tail_len if kv_len is None else jnp.minimum(kv_len, tail_len)
    return _chunked_attn(q, k, v, kv_len, causal, chunk, q_offset, window)


def attention_core(arch: ArchConfig, q, k, v, *, causal: bool,
                   q_offset: int = 0, kv_len=None) -> jax.Array:
    impl = arch.attn_impl
    kwargs = dict(causal=causal, q_offset=q_offset, kv_len=kv_len,
                  window=arch.window)
    if impl == "naive" or k.shape[1] <= arch.attn_chunk or q.shape[1] == 1:
        # single-query decode stays on the un-chunked path: with the KV cache
        # sharded on its length axis the only collectives are [B,H,1] softmax
        # stats + the [B,H,D] output reduction (see parallel/sharding.py).
        return naive_attention(q, k, v, **kwargs)
    if impl in ("chunked", "flash"):
        # "flash" lowers to the Pallas kernel on TPU backends; its CPU/dry-run
        # stand-in is the chunked path (same dataflow at HBM granularity).
        if impl == "flash":
            from ..kernels.flash_attention import ops as flash_ops
            if flash_ops.supported():
                return flash_ops.flash_attention(
                    q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
                    window=arch.window, block_kv=arch.attn_chunk)
        return chunked_attention(q, k, v, chunk=arch.attn_chunk, **kwargs)
    raise ValueError(impl)


# --------------------------------------------------------------- full layer apply -

def apply_attention(arch: ArchConfig, p: PyTree, x: jax.Array,
                    positions: jax.Array, *, causal: bool = True,
                    mrope_positions=None) -> jax.Array:
    """Training/prefill self-attention over the full sequence."""
    b, s, _ = x.shape
    with jax.named_scope("attn_qkv"):
        q, k, v = qkv_project(arch, p, x)
        q, k = position_encode(arch, q, k, positions, mrope_positions)
    # context-parallel attention: query-seq dim sharded on model (always even,
    # unlike head counts — qwen2's 12 heads over 16 devices would churn
    # collective-permutes); k/v replicated over model within the microbatch.
    q = constrain(q, "batch", "seq", None, None)
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)
    with jax.named_scope("attn_core"):
        o = attention_core(arch, q, k, v, causal=causal)
        o = constrain(o, "batch", "seq", None, None)
    with jax.named_scope("attn_out"):
        o = o.reshape(b, s, arch.q_dim)
        return dense(o, p["wo"], p.get("bo"))


def apply_cross_attention(arch: ArchConfig, p: PyTree, x: jax.Array,
                          enc_kv: Tuple[jax.Array, jax.Array]) -> jax.Array:
    """Whisper-style cross attention; enc k/v precomputed [B,Senc,Hkv,Dh]."""
    b, s, _ = x.shape
    hd = arch.resolved_head_dim
    q = dense(x, p["wq"], p.get("bq")).reshape(b, s, arch.num_heads, hd)
    k, v = enc_kv
    o = attention_core(arch, q, k, v, causal=False)
    return dense(o.reshape(b, s, arch.q_dim), p["wo"], p.get("bo"))


def project_enc_kv(arch: ArchConfig, p: PyTree, enc_out: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    b, s, _ = enc_out.shape
    hd = arch.resolved_head_dim
    k = dense(enc_out, p["wk"], p.get("bk")).reshape(b, s, arch.num_kv_heads, hd)
    v = dense(enc_out, p["wv"], p.get("bv")).reshape(b, s, arch.num_kv_heads, hd)
    return k, v


# ------------------------------------------------------------------- decode path --

def init_kv_cache(arch: ArchConfig, batch: int, max_len: int, dtype) -> PyTree:
    hd = arch.resolved_head_dim
    shape = (batch, max_len, arch.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _update_cache_row(cache_row: jax.Array, new_rows: jax.Array,
                      pos: jax.Array) -> jax.Array:
    # cache_row [Smax, Hkv, D]; new_rows [S, Hkv, D]
    return jax.lax.dynamic_update_slice(cache_row, new_rows, (pos, 0, 0))


def extend_attention(arch: ArchConfig, p: PyTree, x: jax.Array,
                     cache: PyTree, positions: jax.Array,
                     mrope_positions=None) -> Tuple[jax.Array, PyTree]:
    """Attend S new tokens against (and into) a KV cache.

    x [B,S,D]; positions [B] = first cache index for the new tokens. S == 1 is
    decode; S > 1 with positions == 0 is prefill (causal among the new tokens).
    """
    b, s, _ = x.shape
    q, k, v = qkv_project(arch, p, x)                        # [B,S,H*,D]
    qpos = positions[:, None] + jnp.arange(s)[None, :]       # [B,S]
    q, k = position_encode(arch, q, k, qpos, mrope_positions)
    if s > 1:
        q = constrain(q, "batch", "seq", None, None)
    else:
        q = constrain(q, "batch", None, None, None)
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)
    new_k = jax.vmap(_update_cache_row)(cache["k"], k, positions)
    new_v = jax.vmap(_update_cache_row)(cache["v"], v, positions)
    if s > 1:
        # prefill (positions == 0 by construction): attend over the fresh K/V —
        # fully local under activation sharding; the cache write above is the
        # one-time [seq->model] cache-layout reshard.
        o = attention_core(arch, q, k, v, causal=True)
    else:
        kv_len = positions + s
        o = attention_core(arch, q, new_k, new_v, causal=False, kv_len=kv_len)
    o = o.reshape(b, s, arch.q_dim)
    y = dense(o, p["wo"], p.get("bo"))
    return y, {"k": new_k, "v": new_v}


def decode_attention(arch: ArchConfig, p: PyTree, x: jax.Array,
                     cache: PyTree, positions: jax.Array,
                     mrope_positions=None) -> Tuple[jax.Array, PyTree]:
    """One-token decode. x [B,1,D]; positions [B] (current index into the cache)."""
    return extend_attention(arch, p, x, cache, positions, mrope_positions)


# ------------------------------------------------------------- paged decode path --

def init_paged_kv_cache(arch: ArchConfig, num_pages: int, page_size: int,
                        dtype) -> PyTree:
    """Global page pool for one attention layer. Page 0 is the null page:
    never allocated to a sequence, it absorbs writes from inactive slots and
    padded page-table entries."""
    hd = arch.resolved_head_dim
    shape = (num_pages, page_size, arch.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _row_parallel_out(p: PyTree, o: jax.Array, x_dtype,
                      tp_axis: Optional[str]) -> jax.Array:
    """Output projection of a paged attention layer.

    Single-device: the plain dense. Under serving TP (inside shard_map) the
    shard's ``wo`` rows cover only its local heads, so the GEMM yields a
    partial sum — psum it over the axis in fp32 and add the (replicated)
    bias once, after the reduce.
    """
    if tp_axis is None:
        return dense(o, p["wo"], p.get("bo"))
    y = o.astype(jnp.float32) @ p["wo"].astype(jnp.float32)
    y = jax.lax.psum(y, tp_axis)
    if "bo" in p:
        y = y + p["bo"].astype(jnp.float32)
    return y.astype(x_dtype)


def paged_prefill_attention_layer(arch: ArchConfig, p: PyTree, x: jax.Array,
                                  cache: PyTree, page_row: jax.Array,
                                  start: jax.Array, total_len: jax.Array,
                                  mrope_positions=None,
                                  tp_axis: Optional[str] = None
                                  ) -> Tuple[jax.Array, PyTree]:
    """One prompt chunk of a single sequence, written directly into its pages.

    x [1, C, D] — chunk token embeddings (row i at absolute position
    start + i); page_row [max_pages] (this sequence's page-table row);
    start = tokens already cached; total_len = start + valid tokens in the
    chunk (the rest of the chunk is padding). K/V rows land straight in the
    page pool — no dense bucket cache, no scatter pass — and padding rows
    (or rows past the allocated pages) are routed to the null page 0.

    With ``tp_axis`` set this body runs per shard: local q/k/v heads, the
    shard's slice of the page pool, and a row-parallel output projection
    psum'd over the axis — the layer's only collective.
    """
    b, c, _ = x.shape
    assert b == 1, "chunked prefill runs one sequence at a time"
    page_size = cache["k"].shape[1]
    max_pages = page_row.shape[0]
    q, k, v = qkv_project(arch, p, x)                        # [1,C,H*,D]
    pos = jnp.asarray(start, jnp.int32) + jnp.arange(c, dtype=jnp.int32)
    q, k = position_encode(arch, q, k, pos[None], mrope_positions)
    logical = pos // page_size
    valid = (pos < total_len) & (logical < max_pages)
    pids = jnp.where(valid,
                     page_row[jnp.clip(logical, 0, max_pages - 1)], 0)
    offs = pos % page_size
    new_k = cache["k"].at[pids, offs].set(k[0])
    new_v = cache["v"].at[pids, offs].set(v[0])
    from ..kernels.decode_attention import ops as pd_ops
    o = pd_ops.paged_prefill_attention(q[0], new_k, new_v, page_row, start,
                                       total_len)
    y = _row_parallel_out(p, o.reshape(1, c, -1), x.dtype, tp_axis)
    return y, {"k": new_k, "v": new_v}


def paged_decode_attention_layer(arch: ArchConfig, p: PyTree, x: jax.Array,
                                 cache: PyTree, page_table: jax.Array,
                                 seq_lens: jax.Array,
                                 mrope_positions=None,
                                 tp_axis: Optional[str] = None
                                 ) -> Tuple[jax.Array, PyTree]:
    """One-token decode against a paged KV cache.

    x [B,1,D]; cache k/v [P, page, Hkv, D]; page_table [B, max_pages];
    seq_lens [B] = tokens already in the cache (the new token's position).
    Inactive slots carry seq_len 0: their K/V lands in the null page and
    their attention output is garbage the engine never reads.

    With ``tp_axis`` set this body runs per shard_map shard (Megatron head
    parallelism): the weight shards project only the local Hq/tp query and
    Hkv/tp KV heads, the cache shard is the local heads' slice of every
    page, and the row-parallel output projection is psum'd over the axis —
    attention's single collective per layer.
    """
    b, s, _ = x.shape
    assert s == 1, "paged path is single-query decode only"
    page_size = cache["k"].shape[1]
    q, k, v = qkv_project(arch, p, x)                        # [B,1,H*,D]
    q, k = position_encode(arch, q, k, seq_lens[:, None], mrope_positions)
    pids = page_table[jnp.arange(b), seq_lens // page_size]  # [B]
    offs = seq_lens % page_size
    new_k = cache["k"].at[pids, offs].set(k[:, 0])
    new_v = cache["v"].at[pids, offs].set(v[:, 0])
    from ..kernels.decode_attention import ops as pd_ops
    o = pd_ops.paged_decode_attention(q[:, 0], new_k, new_v, page_table,
                                      seq_lens + 1)
    y = _row_parallel_out(p, o.reshape(b, 1, -1), x.dtype, tp_axis)
    return y, {"k": new_k, "v": new_v}
