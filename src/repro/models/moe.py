"""Token-choice MoE with sort-based capacity dispatch (drop-on-overflow).

TPU adaptation notes (DESIGN.md §2): the dispatch is *group-local* — tokens are
sorted into expert buckets independently per batch row, so under [batch -> data]
sharding every gather/scatter stays on-device and the only collectives are the same
row-parallel all-reduces a dense MLP needs (expert FF dims are tensor-sharded on the
model axis). An alternative expert-parallel (experts -> model axis, all-to-all
exchange) implementation lives in ``repro.parallel.expert_parallel`` and is compared
in EXPERIMENTS.md §Perf.

FLOP accounting: capacity padding computes on zero slots; ``core.analytical`` reports
both padded and useful MoE FLOPs (the roofline "useful ratio" catches this).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, MoEConfig
from ..parallel.sharding import constrain
from .layers import PyTree, dense_init, silu, gelu


def capacity_per_row(seq: int, moe: MoEConfig) -> int:
    return max(1, math.ceil(seq * moe.top_k * moe.capacity_factor / moe.num_experts))


# ------------------------------------------------------------------------- init ---

def init_moe(key, arch: ArchConfig, dtype=jnp.float32) -> PyTree:
    moe = arch.moe
    assert moe is not None
    d = arch.d_model
    eff = moe.expert_ff or arch.d_ff
    ks = jax.random.split(key, 8)
    std = 1.0 / math.sqrt(d)
    stdf = 1.0 / math.sqrt(eff)
    p: PyTree = {
        "router": (jax.random.normal(ks[0], (d, moe.num_experts)) * std
                   ).astype(jnp.float32),           # router kept fp32 (numerics)
        "experts": {
            "w1": (jax.random.truncated_normal(ks[1], -2, 2,
                                               (moe.num_experts, d, eff)) * std
                   ).astype(dtype),
            "w3": (jax.random.truncated_normal(ks[2], -2, 2,
                                               (moe.num_experts, d, eff)) * std
                   ).astype(dtype),
            "w2": (jax.random.truncated_normal(ks[3], -2, 2,
                                               (moe.num_experts, eff, d)) * stdf
                   ).astype(dtype),
        },
    }
    if moe.num_shared_experts:
        shared_ff = eff * moe.num_shared_experts
        p["shared"] = {
            "w1": dense_init(ks[4], d, shared_ff, dtype),
            "w3": dense_init(ks[5], d, shared_ff, dtype),
            "w2": dense_init(ks[6], shared_ff, d, dtype),
        }
    return p


# ------------------------------------------------------------------ sort dispatch -

def _route_indices(logits: jax.Array, moe: MoEConfig, capacity: int,
                   eff_capacity: Optional[jax.Array] = None):
    """Per-batch-row routing *index* math (cheap int ops; vmapped over rows).

    logits [S, E] fp32 -> (st [S*k] source token ids, sw [S*k] weights,
    slot [S*k] capacity-slot ids incl. overflow sentinel, valid [S*k]).

    ``capacity`` sizes the dispatch buffer (static); ``eff_capacity`` — a
    traced scalar — optionally *tightens* the drop threshold below it. The
    chunked-prefill path passes the full prompt's capacity here so a prompt
    served in one padded chunk reproduces the static engine's drop pattern
    exactly: the chunk's trailing padding cannot displace real tokens (the
    stable expert sort keeps padded entries after every real one), but the
    padded shape would otherwise inflate the capacity bucket.
    """
    s, e = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)                   # [S, E]
    top_w, top_ids = jax.lax.top_k(probs, moe.top_k)          # [S, k]
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    flat_e = top_ids.reshape(-1)                              # [S*k]
    flat_t = jnp.repeat(jnp.arange(s), moe.top_k)             # [S*k]
    flat_w = top_w.reshape(-1)

    order = jnp.argsort(flat_e)                               # stable
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    start = jnp.searchsorted(se, jnp.arange(e), side="left")  # [E]
    pos = jnp.arange(s * moe.top_k) - start[se]
    limit = capacity if eff_capacity is None \
        else jnp.minimum(capacity, eff_capacity)
    valid = pos < limit
    slot = jnp.where(valid, se * capacity + pos, e * capacity)
    return st, sw, slot, valid


def apply_moe(arch: ArchConfig, p: PyTree, x: jax.Array,
              tp_axis: Optional[str] = None,
              eff_capacity: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """x [B, S, D] -> (y [B, S, D], aux_loss scalar).

    ``tp_axis``: serving tensor parallelism — the call runs inside
    ``shard_map`` on a shard holding ``num_experts / tp`` contiguous experts
    (leading axis of ``p["experts"]``), the router and activations
    replicated. Routing stays global (every shard sees the full top-k over
    all E experts); each shard dispatches, computes, and combines only the
    capacity slots of the experts it owns, and the partial combines meet in
    one fp32 psum — the MoE layer's single collective, in place of the dense
    MLP's row-parallel reduce.

    ``eff_capacity`` (traced scalar): tightens the per-row drop threshold
    below the buffer capacity. The chunked-prefill path passes the full
    prompt's ``capacity_per_row`` so a single-chunk prompt drops exactly the
    tokens the static engine's full-prompt dispatch would drop, instead of
    a bucket inflated by the chunk's padded shape.
    """
    moe = arch.moe
    b, s, d = x.shape
    cap = capacity_per_row(s, moe)
    with jax.named_scope("moe"):
        return _apply_moe_inner(arch, p, x, moe, cap, tp_axis, eff_capacity)


def _apply_moe_inner(arch, p, x, moe, cap, tp_axis=None, eff_capacity=None):
    b, s, d = x.shape
    e = moe.num_experts
    w = p["experts"]
    local_e = w["w1"].shape[0]          # experts this shard owns (== e at tp=1)
    logits = (x.astype(jnp.float32) @ p["router"])            # [B, S, E]

    st, sw, slot, valid = jax.vmap(
        lambda lr: _route_indices(lr, moe, cap, eff_capacity))(logits)
    if tp_axis is not None:
        # expert parallelism under shard_map: rebase global capacity-slot ids
        # onto this shard's experts; slots owned elsewhere fold into the
        # overflow sentinel so they neither dispatch nor combine here
        off = jax.lax.axis_index(tp_axis) * local_e * cap
        slot = slot - off
        valid = valid & (slot >= 0) & (slot < local_e * cap)
        slot = jnp.where(valid, slot, local_e * cap)

    def dispatch_row(xr, st_r, slot_r, valid_r):
        gathered = xr[st_r] * valid_r[:, None].astype(xr.dtype)   # [S*k, D]
        slots_r = jnp.zeros((local_e * cap + 1, d), xr.dtype)
        slots_r = slots_r.at[slot_r].add(gathered)
        return slots_r[:-1].reshape(local_e, cap, d)

    slots = jax.vmap(dispatch_row)(x, st, slot, valid)        # [B, El, C, D]

    # expert parallelism (training/pjit path): slots all-to-all from
    # [B->data] row-local layout into [E->model] expert-owner layout; each
    # device runs its E/16 experts' GEMMs. (Identity inside shard_map.)
    slots = constrain(slots, "batch", "experts", None, None)
    act = silu if arch.mlp == "swiglu" else gelu
    h = act(jnp.einsum("becd,edf->becf", slots, w["w1"].astype(x.dtype)))
    if arch.mlp == "swiglu":
        h = h * jnp.einsum("becd,edf->becf", slots, w["w3"].astype(x.dtype))
    h = constrain(h, "batch", "experts", None, None)
    out = jnp.einsum("becf,efd->becd", h, w["w2"].astype(x.dtype))
    out = constrain(out, "batch", "experts", None, None)

    def combine_row(out_r, st_r, sw_r, slot_r, valid_r):
        flat = jnp.concatenate(
            [out_r.reshape(local_e * cap, d), jnp.zeros((1, d), out_r.dtype)],
            0)
        contrib = flat[slot_r] * (sw_r * valid_r).astype(out_r.dtype)[:, None]
        y_r = jnp.zeros((s, d), out_r.dtype)
        return y_r.at[st_r].add(contrib)

    y = jax.vmap(combine_row)(out, st, sw, slot, valid)
    y = constrain(y, "batch", "seq", None)

    if "shared" in p:
        # shared experts are a dense MLP: under tp_axis their weights are the
        # usual Megatron column/row shards, and the row-parallel partial sum
        # rides the same psum as the routed combine below
        sh = p["shared"]
        hs = silu(x @ sh["w1"].astype(x.dtype)) * (x @ sh["w3"].astype(x.dtype))
        y = y + hs @ sh["w2"].astype(x.dtype)
    if tp_axis is not None:
        y = jax.lax.psum(y.astype(jnp.float32), tp_axis).astype(x.dtype)

    # Switch-style load-balancing aux loss: E * sum_e f_e * P_e
    probs = jax.nn.softmax(logits, axis=-1)                   # [B,S,E] fp32
    top1 = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, moe.num_experts, dtype=jnp.float32),
                 axis=(0, 1))
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = moe.num_experts * jnp.sum(f * pmean) * moe.aux_loss_weight
    return y, aux
