"""Primitive layers: inits, norms, embeddings, rotary embeddings, activations.

All models are pure functions over nested-dict parameter pytrees. Parameter *names*
are the contract with ``repro.parallel.sharding`` (path-pattern -> PartitionSpec), so
naming here is deliberate and stable:

  embedding           [V, D]     vocab-sharded
  head                [D, V]     vocab-sharded (column-parallel)
  wq/wk/wv/wqkv       [D, *]     column-parallel (output feature dim -> model axis)
  w1/w3               [D, F]     column-parallel
  wo/w2               [*, D]     row-parallel (input feature dim -> model axis)
  experts.*           [E, ., .]  expert-batched, TP on F (or EP on E)
  scale/bias          [D]        replicated
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Dict[str, object]

VOCAB_PAD = 128  # pad vocab to a multiple of this (Megatron-style); keeps 16-way TP legal


def pad_vocab(v: int) -> int:
    return ((v + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


# ----------------------------------------------------------------- initializers ---

def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    """Truncated-normal fan-in init (matches common LM practice)."""
    std = 1.0 / math.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim)) * std
            ).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------- dense ----

def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


# ----------------------------------------------------------------------- norms ----

def init_norm(kind: str, dim: int, dtype=jnp.float32) -> PyTree:
    p: PyTree = {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(kind: str, p: PyTree, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm / LayerNorm with fp32 statistics (bf16-safe)."""
    with jax.named_scope("norm"):
        return _apply_norm(kind, p, x, eps)


def _apply_norm(kind: str, p: PyTree, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(dt)


# ----------------------------------------------------------------- activations ----

def gelu(x: jax.Array) -> jax.Array:
    # tanh approximation — matches BERT's GeLU (paper §3.2.3)
    return jax.nn.gelu(x, approximate=True)


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def softplus(x: jax.Array) -> jax.Array:
    return jax.nn.softplus(x)


# ------------------------------------------------------------------ embeddings ----

def init_embedding(key, vocab: int, dim: int, dtype=jnp.float32) -> PyTree:
    return {"embedding": embed_init(key, pad_vocab(vocab), dim, dtype)}


def embed_tokens(p: PyTree, tokens: jax.Array, dtype) -> jax.Array:
    return p["embedding"].astype(dtype)[tokens]


def unembed(p: PyTree, x: jax.Array, tied_embedding: Optional[jax.Array],
            softcap: float = 0.0) -> jax.Array:
    """Logits in fp32 (loss numerics), vocab-sharded on the model axis."""
    from ..parallel.sharding import constrain
    if tied_embedding is not None:
        w = tied_embedding.astype(x.dtype).T
    else:
        w = p["head"].astype(x.dtype)
    # unshard the weight's fsdp (embed) dim for the head matmul: otherwise the
    # backward dx contraction maps both output dims to the data axis and GSPMD
    # resolves it by all-gathering the fp32 [B,S,V] logit cotangent (33 GB/dev
    # at command-r's 256k vocab) instead of this 0.26 GB weight gather.
    w = constrain(w, None, "vocab")
    logits = (x @ w).astype(jnp.float32)
    logits = constrain(logits, "batch", None, "vocab")
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def sinusoidal_positions(seq: int, dim: int, dtype=jnp.float32,
                         offset: int = 0) -> jax.Array:
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, 2.0 * i / dim)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1).astype(dtype)


# ------------------------------------------------------------------------ RoPE ----

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # [D/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs   # [..., S, D/2]
    ang = ang[..., None, :]                                  # [..., S, 1, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions_thw: jax.Array, theta: float,
                sections: Tuple[float, float, float] = (0.5, 0.25, 0.25)) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: [B, S, H, D]; positions_thw: [3, B, S] (temporal, height, width ids).
    The D/2 frequency dims are partitioned into contiguous (t, h, w) sections; for
    text-only inputs where t==h==w this reduces exactly to standard RoPE (tested).
    """
    d = x.shape[-1]
    half = d // 2
    n_t = int(half * sections[0])
    n_h = int(half * sections[1])
    n_w = half - n_t - n_h
    freqs = rope_frequencies(d, theta)                       # [D/2]
    # pick the position stream per frequency-dim section
    sec = jnp.concatenate([
        jnp.zeros((n_t,), jnp.int32),
        jnp.ones((n_h,), jnp.int32),
        jnp.full((n_w,), 2, jnp.int32),
    ])                                                       # [D/2]
    pos = positions_thw.astype(jnp.float32)                  # [3, B, S]
    # ang[b, s, j] = pos[sec[j], b, s] * freqs[j]
    pos_sel = jnp.take(pos, sec, axis=0)                     # [D/2, B, S]
    ang = jnp.moveaxis(pos_sel, 0, -1) * freqs               # [B, S, D/2]
    ang = ang[..., None, :]                                  # [B, S, 1, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------------- MLP ----

def init_mlp(key, kind: str, d_model: int, d_ff: int, use_bias: bool,
             dtype=jnp.float32) -> PyTree:
    ks = jax.random.split(key, 3)
    p: PyTree = {"w1": dense_init(ks[0], d_model, d_ff, dtype),
                 "w2": dense_init(ks[1], d_ff, d_model, dtype)}
    if kind == "swiglu":
        p["w3"] = dense_init(ks[2], d_model, d_ff, dtype)
    if use_bias:
        p["b1"] = jnp.zeros((d_ff,), dtype)
        p["b2"] = jnp.zeros((d_model,), dtype)
        if kind == "swiglu":
            p["b3"] = jnp.zeros((d_ff,), dtype)
    return p


def apply_mlp(kind: str, p: PyTree, x: jax.Array,
              tp_axis: Optional[str] = None, *,
              fused: bool = False) -> jax.Array:
    with jax.named_scope("mlp"):
        return _apply_mlp(kind, p, x, tp_axis, fused=fused)


def _apply_mlp(kind: str, p: PyTree, x: jax.Array,
               tp_axis: Optional[str] = None, *,
               fused: bool = False) -> jax.Array:
    """Feed-forward block. With ``tp_axis`` set (serving TP under shard_map)
    the params are the Megatron shards — w1/w3 column-parallel, w2
    row-parallel — so the local GEMM yields a *partial* output that is
    psum'd over the axis in fp32, and w2's bias is added once, after the
    reduce (a pre-psum add would count it tp times). ``fused`` routes a
    gelu MLP's bias+activation through ``kernels.bias_gelu`` (one VMEM pass
    instead of a GEMM-out write + bias read + gelu read; no-op for swiglu,
    whose epilogue is the gated product, not bias+gelu)."""
    if kind == "swiglu":
        h = silu(dense(x, p["w1"], p.get("b1"))) * dense(x, p["w3"], p.get("b3"))
    elif kind == "gelu":
        if fused:
            from ..kernels.bias_gelu import ops as bg_ops
            h = bg_ops.bias_gelu(dense(x, p["w1"]), p.get("b1"))
        else:
            h = gelu(dense(x, p["w1"], p.get("b1")))
    else:
        raise ValueError(kind)
    if tp_axis is None:
        return dense(h, p["w2"], p.get("b2"))
    y = h.astype(jnp.float32) @ p["w2"].astype(jnp.float32)
    y = jax.lax.psum(y, tp_axis)
    if "b2" in p:
        y = y + p["b2"].astype(jnp.float32)
    return y.astype(x.dtype)
