"""Model facade: ``build_model(arch)`` -> init / forward / loss / prefill / decode.

One code path serves all 10 assigned architectures + BERT-Large:
  dense / moe / vlm : decoder-only LM (BERT flips ``bidirectional`` + MLM head)
  ssm / hybrid      : mamba2 or interleaved stacks, same embedding/head
  encdec            : whisper — encoder over stubbed frame embeddings + causal
                      decoder with per-layer cross attention
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import transformer as tf
from .layers import (PyTree, apply_norm, dense, dense_init, embed_tokens, gelu,
                     init_embedding, init_norm, pad_vocab, sinusoidal_positions,
                     unembed)

Batch = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class Model:
    arch: ArchConfig
    fuse_qkv: bool = True

    # ------------------------------------------------------------------ init ----
    def init(self, key: jax.Array) -> PyTree:
        arch = self.arch
        dtype = jnp.dtype(arch.param_dtype)
        ks = jax.random.split(key, 8)
        p: PyTree = {"embed": init_embedding(ks[0], arch.vocab_size,
                                             arch.d_model, dtype)}
        if arch.pos_emb == "learned":
            p["pos"] = {"pos_embedding":
                        (jax.random.normal(ks[1], (arch.max_position,
                                                   arch.d_model)) * 0.02
                         ).astype(dtype)}
        if arch.family == "encdec":
            p["enc_blocks"] = tf.init_stack(ks[2], arch, self.fuse_qkv, dtype,
                                            num_layers=arch.enc_layers)
            p["enc_final_norm"] = init_norm(arch.norm, arch.d_model, dtype)
            p["blocks"] = tf.init_stack(ks[3], arch, self.fuse_qkv, dtype,
                                        cross=True)
        else:
            p["blocks"] = tf.init_stack(ks[3], arch, self.fuse_qkv, dtype)
        p["final_norm"] = init_norm(arch.norm, arch.d_model, dtype)
        if not arch.tie_embeddings:
            p["out"] = {"head": dense_init(ks[4], arch.d_model,
                                           pad_vocab(arch.vocab_size), dtype)}
        if arch.mlm_transform:
            p["mlm"] = {"dense": dense_init(ks[5], arch.d_model, arch.d_model,
                                            dtype),
                        "bias": jnp.zeros((arch.d_model,), dtype),
                        "ln": init_norm(arch.norm, arch.d_model, dtype)}
        return p

    # --------------------------------------------------------------- helpers ----
    def _embed(self, p: PyTree, tokens: jax.Array, offset: int = 0) -> jax.Array:
        arch = self.arch
        dtype = jnp.dtype(arch.dtype)
        x = embed_tokens(p["embed"], tokens, dtype)
        s = tokens.shape[1]
        if arch.pos_emb == "learned":
            x = x + p["pos"]["pos_embedding"][offset:offset + s].astype(dtype)
        elif arch.pos_emb == "sinusoidal" and arch.family != "encdec":
            x = x + sinusoidal_positions(s, arch.d_model, dtype, offset)
        return x

    def _logits(self, p: PyTree, x: jax.Array) -> jax.Array:
        arch = self.arch
        with jax.named_scope("logits"):
            return self._logits_inner(p, x)

    def _logits_inner(self, p: PyTree, x: jax.Array) -> jax.Array:
        arch = self.arch
        from ..parallel.sharding import constrain
        # unshard the seq dim before the head: the model axis carries the vocab
        # sharding of logits from here on. Without this GSPMD all-gathers the
        # fp32 [B,S,V] logit cotangent (33 GB/device for command-r) instead of
        # the small [B,S,D] activations when forming the head weight grad.
        x = constrain(x, "batch", None, None)
        x = apply_norm(arch.norm, p["final_norm"], x)
        if arch.mlm_transform:
            x = gelu(dense(x, p["mlm"]["dense"], p["mlm"]["bias"]))
            x = apply_norm(arch.norm, p["mlm"]["ln"], x)
        tied = p["embed"]["embedding"] if arch.tie_embeddings else None
        return unembed(p.get("out", {}), x, tied, arch.logit_softcap)

    def _encode(self, p: PyTree, frontend_embeddings: jax.Array) -> jax.Array:
        """Whisper encoder over stubbed conv-frontend frame embeddings."""
        arch = self.arch
        dtype = jnp.dtype(arch.dtype)
        x = frontend_embeddings.astype(dtype)
        s = x.shape[1]
        x = x + sinusoidal_positions(s, arch.d_model, dtype)
        positions = jnp.arange(s)[None]
        x, _ = tf.apply_stack(arch, p["enc_blocks"], x, positions, causal=False)
        return apply_norm(arch.norm, p["enc_final_norm"], x)

    # ----------------------------------------------------------------- train ----
    def forward(self, p: PyTree, batch: Batch) -> Tuple[jax.Array, jax.Array]:
        """-> (logits [B,S,Vp] fp32, aux_loss)."""
        arch = self.arch
        tokens = batch["tokens"]
        with jax.named_scope("embed"):
            x = self._embed(p, tokens)
        positions = jnp.arange(tokens.shape[1])[None]
        enc_out = None
        if arch.family == "encdec":
            enc_out = self._encode(p, batch["frontend_embeddings"])
        x, aux = tf.apply_stack(arch, p["blocks"], x, positions,
                                causal=not arch.bidirectional,
                                mrope_positions=batch.get("mrope_positions"),
                                enc_out=enc_out)
        return self._logits(p, x), aux

    def loss(self, p: PyTree, batch: Batch) -> Tuple[jax.Array, Dict[str, Any]]:
        logits, aux = self.forward(p, batch)
        with jax.named_scope("loss"):
            ce, acc = cross_entropy(logits, batch["targets"], batch.get("loss_mask"))
        total = ce + aux
        return total, {"loss": total, "ce": ce, "aux": aux, "accuracy": acc}

    # ----------------------------------------------------------------- serve ----
    def init_caches(self, p_or_none, batch: int, max_len: int) -> PyTree:
        return tf.init_caches(self.arch, batch, max_len,
                              jnp.dtype(self.arch.dtype))

    def prefill(self, p: PyTree, caches: PyTree, batch: Batch
                ) -> Tuple[jax.Array, PyTree]:
        """Fill caches from a [B, S] prompt; -> (last-position logits, caches)."""
        arch = self.arch
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = self._embed(p, tokens)
        if arch.family == "encdec":
            enc_out = self._encode(p, batch["frontend_embeddings"])
            caches = self._fill_cross_kv(p, caches, enc_out)
        positions = jnp.zeros((b,), jnp.int32)
        x, caches = tf.decode_stack(arch, p["blocks"], caches, x, positions,
                                    batch.get("mrope_positions"))
        logits = self._logits(p, x[:, -1:])
        return logits, caches

    def decode_step(self, p: PyTree, caches: PyTree, batch: Batch
                    ) -> Tuple[jax.Array, PyTree]:
        """One token for every sequence. batch: tokens [B,1], positions [B]."""
        arch = self.arch
        x = self._embed(p, batch["tokens"])
        if arch.pos_emb == "learned":
            # re-add at the right offset (decode): gather per-batch position row
            x = (embed_tokens(p["embed"], batch["tokens"], jnp.dtype(arch.dtype))
                 + p["pos"]["pos_embedding"][batch["positions"]][:, None].astype(x.dtype))
        x, caches = tf.decode_stack(arch, p["blocks"], caches, x,
                                    batch["positions"],
                                    batch.get("mrope_positions"))
        return self._logits(p, x), caches

    def _fill_cross_kv(self, p: PyTree, caches: PyTree, enc_out: jax.Array
                       ) -> PyTree:
        from . import attention as attn_lib
        arch = self.arch

        def fill(period_params, period_cache):
            for i in range(tf.period_length(arch)):
                blk = period_params[f"layer_{i}"]
                if "xattn" in blk:
                    k, v = attn_lib.project_enc_kv(arch, blk["xattn"], enc_out)
                    period_cache[f"layer_{i}"]["cross_k"] = k
                    period_cache[f"layer_{i}"]["cross_v"] = v
            return period_cache

        if isinstance(p["blocks"], dict) and any(
                k.startswith("period_") for k in p["blocks"]):
            return {z: fill(p["blocks"][z], dict(caches[z])) for z in caches}
        return jax.vmap(fill)(p["blocks"], caches)


def _ce_pieces(logits, targets, mask):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # vocab-parallel target pick (Megatron-style): a gather over the
    # vocab-sharded axis would make GSPMD all-gather the fp32 logits; the
    # masked-sum fuses into the sharded reduce instead. Likewise accuracy via
    # max-compare — argmax lowers to a full [B,S,V] s32 iota reduce.
    vocab_ids = jnp.arange(logits.shape[-1])[None, None, :]
    onehot = vocab_ids == targets[..., None]
    target_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    ll = target_logit - lse
    correct = (target_logit >= jnp.max(logits, axis=-1)).astype(jnp.float32)
    if mask is None:
        m = jnp.ones(targets.shape, jnp.float32)
    else:
        m = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(m), 1.0)
    ce = -jnp.sum(ll * m) / denom
    acc = jnp.sum(correct * m) / denom
    return ce, acc, lse, m, denom


@jax.custom_vjp
def _ce_loss(logits, targets, mask):
    ce, acc, _, _, _ = _ce_pieces(logits, targets, mask)
    return ce, acc


def _ce_loss_fwd(logits, targets, mask):
    ce, acc, lse, m, denom = _ce_pieces(logits, targets, mask)
    return (ce, acc), (logits, targets, lse, m, denom)


def _ce_loss_bwd(res, cot):
    """Hand-written vocab-parallel CE backward.

    dlogits = g * (softmax - onehot) * mask / denom, kept vocab-sharded via an
    explicit constraint — autodiff's broadcast-formed onehot cotangent anchored
    GSPMD to a *replicated* fp32 [B,S,V] (33 GB/device at command-r's 256k
    vocab; see EXPERIMENTS.md §Perf iteration log).
    """
    from ..parallel.sharding import constrain
    logits, targets, lse, m, denom = res
    g_ce, _ = cot
    p = jnp.exp(logits.astype(jnp.float32) - lse[..., None])
    vocab_ids = jnp.arange(logits.shape[-1])[None, None, :]
    onehot = (vocab_ids == targets[..., None]).astype(jnp.float32)
    scale = (g_ce * m / denom)[..., None]
    dlogits = (p - onehot) * scale
    dlogits = constrain(dlogits, "batch", None, "vocab")
    return dlogits.astype(logits.dtype), None, None


_ce_loss.defvjp(_ce_loss_fwd, _ce_loss_bwd)


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Masked softmax cross-entropy in fp32. logits [B,S,V]; targets [B,S]."""
    return _ce_loss(logits, targets, mask)


def build_model(arch: ArchConfig, fuse_qkv: bool = True) -> Model:
    return Model(arch, fuse_qkv)
