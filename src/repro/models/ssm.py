"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060), TPU-adapted.

The chunked SSD algorithm recasts the selective-scan recurrence as batched GEMMs over
length-``Q`` chunks: a [Q, Q] intra-chunk "attention-like" term plus an inter-chunk
state recurrence of [N, P] states. On TPU this is exactly the paper's
"not-all-GEMMs-are-equal" story — the chunk GEMMs are the small/skinny ones, sized by
(Q, N, P) rather than (S, d_model) — and the sequential part shrinks from S steps to
S/Q steps of cheap elementwise state decay.

Train/prefill: ``ssd_chunked``. Decode: ``ssd_decode_step`` (constant-size state).
All decay/cum-sum math in fp32.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, SSMConfig
from ..kernels.fused_layernorm import ops as ln_ops
from ..parallel.sharding import constrain
from .layers import PyTree, dense_init, silu, softplus


def inner_dim(arch: ArchConfig) -> int:
    return arch.ssm.expand * arch.d_model


def num_ssm_heads(arch: ArchConfig) -> int:
    return inner_dim(arch) // arch.ssm.head_dim


def conv_channels(arch: ArchConfig) -> int:
    s = arch.ssm
    return inner_dim(arch) + 2 * s.ngroups * s.state_dim


# ------------------------------------------------------------------------- init ---

def init_mamba(key, arch: ArchConfig, dtype=jnp.float32) -> PyTree:
    s = arch.ssm
    d = arch.d_model
    inner = inner_dim(arch)
    h = num_ssm_heads(arch)
    proj_out = 2 * inner + 2 * s.ngroups * s.state_dim + h
    ks = jax.random.split(key, 5)
    # A in [-~8, -~0.5): standard mamba2 init A_log ~ log U[1, 16]
    a_log = jnp.log(jax.random.uniform(ks[2], (h,), minval=1.0, maxval=16.0))
    dt = jnp.exp(jax.random.uniform(ks[3], (h,),
                 minval=jnp.log(1e-3), maxval=jnp.log(1e-1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))                  # inverse softplus
    return {
        "in_proj": dense_init(ks[0], d, proj_out, dtype),
        "conv": (jax.random.normal(ks[1], (s.conv_width, conv_channels(arch)))
                 * (1.0 / s.conv_width)).astype(dtype),
        "A_log": a_log.astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_scale": jnp.ones((inner,), dtype),
        "out_proj": dense_init(ks[4], inner, d, dtype),
    }


# ------------------------------------------------------------------- SSD chunked --

def _segsum(x: jax.Array) -> jax.Array:
    """x [..., Q] -> [..., Q, Q] lower-triangular pairwise sums: out[i,j]=sum(x[j+1..i])."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array,
                b: jax.Array, c: jax.Array, chunk: int,
                initial_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD over full sequences.

    x  [B, S, H, P]   inputs per head
    dt [B, S, H]      positive step sizes (already softplus'd)
    a  [H]            negative decay rates
    b  [B, S, G, N]   input projections (shared across H/G heads per group)
    c  [B, S, G, N]   output projections
    -> (y [B, S, H, P], final_state [B, H, N, P])
    """
    bsz, seq, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert seq % chunk == 0, (seq, chunk)
    nc = seq // chunk
    rep = h // g

    # chunk views
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bc = b.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    cc = c.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    da = dtc * a[None, None, None, :]                         # [B,nc,Q,H] (negative)
    xdt = (xc.astype(jnp.float32) * dtc[..., None])           # [B,nc,Q,H,P]
    # chunk dim == sequence dim: shard on model like the residual stream. The
    # [.., H, Q, Q] decay tensors are the big SSD intermediates (1 GB+/layer for
    # jamba); chunk-sharding keeps them 1/16 per device.
    da = constrain(da, "batch", "seq", None, None)
    xdt = constrain(xdt, "batch", "seq", None, None, None)

    # ---- intra-chunk (diagonal) term: 'attention' with decay mask ----
    # L[i,j] = exp(segsum(da))  (i >= j)
    lmat = jnp.exp(_segsum(jnp.moveaxis(da, -1, 2)))          # [B,nc,H,Q,Q]
    lmat = constrain(lmat, "batch", "seq", None, None, None)
    scores = jnp.einsum("bzqgn,bzkgn->bzgqk", cc, bc)         # [B,nc,G,Q,Q]
    scores = jnp.repeat(scores, rep, axis=2)                  # [B,nc,H,Q,Q]
    y_diag = jnp.einsum("bzhqk,bzkhp->bzqhp", scores * lmat, xdt)

    # ---- chunk states: S_z = sum_k decay_to_end[k] * b[k] (x dt)[k] ----
    cum = jnp.cumsum(da, axis=2)                              # [B,nc,Q,H]
    total = cum[:, :, -1:, :]                                 # [B,nc,1,H]
    decay_to_end = jnp.exp(total - cum)                       # [B,nc,Q,H]
    bh = jnp.repeat(bc, rep, axis=3)                          # [B,nc,Q,H,N]
    states = jnp.einsum("bzqhn,bzqhp->bzhnp", bh * decay_to_end[..., None], xdt)
    # keep the einsum chunk-sharded (unsharding its output here would force
    # GSPMD to all-gather the big [B,nc,Q,H,*] operands)
    states = constrain(states, "batch", "seq", None, None, None)

    # ---- inter-chunk recurrence over nc (sequential, cheap) ----
    chunk_decay = jnp.exp(total[:, :, 0, :])                  # [B,nc,H]

    def body(s_in, inputs):
        dec, s_chunk = inputs                                 # [B,H], [B,H,N,P]
        s_out = s_in * dec[..., None, None] + s_chunk
        return s_out, s_in                                    # emit state *entering* chunk

    s0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((bsz, h, n, p), jnp.float32))
    # the sequential scan slices per chunk: its (small) per-chunk states must be
    # replicated over model — constrain only the scan-order copies
    dec_seq = constrain(jnp.moveaxis(chunk_decay, 1, 0), None, "batch", None)
    states_seq = constrain(jnp.moveaxis(states, 1, 0),
                           None, "batch", None, None, None)
    final, s_in_seq = jax.lax.scan(body, s0, (dec_seq, states_seq))
    s_in_seq = jnp.moveaxis(s_in_seq, 0, 1)                   # [B,nc,H,N,P]
    s_in_seq = constrain(s_in_seq, "batch", "seq", None, None, None)

    # ---- inter-chunk output: y_off = (c * exp(cum)) @ state_in ----
    ch = jnp.repeat(cc, rep, axis=3)                          # [B,nc,Q,H,N]
    y_off = jnp.einsum("bzqhn,bzhnp->bzqhp", ch * jnp.exp(cum)[..., None], s_in_seq)

    y = (y_diag + y_off).reshape(bsz, seq, h, p)
    return y.astype(x.dtype), final


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array, a: jax.Array,
                    b: jax.Array, c: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token SSD update.

    state [B,H,N,P]; x [B,H,P]; dt [B,H]; b,c [B,G,N] -> (y [B,H,P], new state)
    """
    h = x.shape[1]
    g = b.shape[1]
    rep = h // g
    bh = jnp.repeat(b, rep, axis=1).astype(jnp.float32)       # [B,H,N]
    ch = jnp.repeat(c, rep, axis=1).astype(jnp.float32)
    da = jnp.exp(dt.astype(jnp.float32) * a[None, :])         # [B,H]
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    new_state = (state * da[..., None, None]
                 + jnp.einsum("bhn,bhp->bhnp", bh, xdt))
    y = jnp.einsum("bhn,bhnp->bhp", ch, new_state)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------- mamba block -----

def _causal_conv(seq_in: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. seq_in [B,S,C]; w [W,C]."""
    width = w.shape[0]
    pad = jnp.pad(seq_in, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(seq_in, dtype=jnp.float32)
    # convention: w[width-1] multiplies the current timestep (matches decode path)
    for i in range(width):                                    # width is 4: unrolled
        out = out + pad[:, i:i + seq_in.shape[1], :].astype(jnp.float32) * \
            w[i][None, None, :].astype(jnp.float32)
    return out.astype(seq_in.dtype)


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array,
                   eps: float = 1e-5) -> jax.Array:
    """SiLU-gated RMSNorm of the mixer output. The canonical math lives in
    ``kernels.fused_layernorm.ref.gated_rmsnorm`` (this delegates); on TPU
    the ops wrapper runs it as one fused VMEM pass, bit-identically — so
    every mamba call site (train, prefill chunks, decode) picks up the
    fusion without a flag."""
    return ln_ops.gated_rmsnorm(y, z, scale, eps=eps)


def _split_proj(arch: ArchConfig, zxbcdt: jax.Array):
    s = arch.ssm
    inner = inner_dim(arch)
    h = num_ssm_heads(arch)
    gn = s.ngroups * s.state_dim
    return jnp.split(zxbcdt, [inner, 2 * inner, 2 * inner + gn,
                              2 * inner + 2 * gn], axis=-1)   # z, x, B, C, dt


def apply_mamba(arch: ArchConfig, p: PyTree, u: jax.Array) -> jax.Array:
    """Full-sequence mamba2 block. u [B,S,D] -> [B,S,D]."""
    with jax.named_scope("mamba"):
        return _apply_mamba(arch, p, u)


def _apply_mamba(arch: ArchConfig, p: PyTree, u: jax.Array) -> jax.Array:
    s = arch.ssm
    bsz, seq, _ = u.shape
    inner = inner_dim(arch)
    h = num_ssm_heads(arch)
    zxbcdt = u @ p["in_proj"].astype(u.dtype)
    z, xin, b, c, dt = _split_proj(arch, zxbcdt)
    xbc = jnp.concatenate([xin, b, c], axis=-1)
    xbc = silu(_causal_conv(xbc, p["conv"]))
    xin, b, c = jnp.split(xbc, [inner, inner + s.ngroups * s.state_dim], axis=-1)
    dt = softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["A_log"])
    xh = xin.reshape(bsz, seq, h, s.head_dim)
    bg = b.reshape(bsz, seq, s.ngroups, s.state_dim)
    cg = c.reshape(bsz, seq, s.ngroups, s.state_dim)
    chunk = min(s.chunk, seq)
    y, _ = ssd_chunked(xh, dt, a, bg, cg, chunk)
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bsz, seq, inner)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    return y @ p["out_proj"].astype(u.dtype)


# ------------------------------------------------------------------ decode path ---

def init_mamba_cache(arch: ArchConfig, batch: int, dtype) -> PyTree:
    s = arch.ssm
    h = num_ssm_heads(arch)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_channels(arch)), dtype),
        "state": jnp.zeros((batch, h, s.state_dim, s.head_dim), jnp.float32),
    }


def extend_mamba(arch: ArchConfig, p: PyTree, u: jax.Array, cache: PyTree
                 ) -> Tuple[jax.Array, PyTree]:
    """Prefill S tokens through a mamba block, threading conv window + SSD state.

    u [B,S,D] with S a multiple of the SSD chunk (or S small enough to pad).
    """
    s = arch.ssm
    bsz, seq, _ = u.shape
    if seq == 1:
        return decode_mamba(arch, p, u, cache)
    inner = inner_dim(arch)
    h = num_ssm_heads(arch)
    zxbcdt = u @ p["in_proj"].astype(u.dtype)
    z, xin, b, c, dt = _split_proj(arch, zxbcdt)
    xbc = jnp.concatenate([xin, b, c], axis=-1)              # [B,S,C]
    # conv with cached left context
    ctx = jnp.concatenate([cache["conv"], xbc], axis=1)      # [B,W-1+S,C]
    width = s.conv_width
    conv_out = jnp.zeros((bsz, seq, xbc.shape[-1]), jnp.float32)
    for i in range(width):
        conv_out = conv_out + ctx[:, i:i + seq].astype(jnp.float32) * \
            p["conv"][i][None, None].astype(jnp.float32)
    new_conv_cache = ctx[:, -(width - 1):]
    xbc = silu(conv_out.astype(u.dtype))
    xin, b, c = jnp.split(xbc, [inner, inner + s.ngroups * s.state_dim], axis=-1)
    dt = softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["A_log"])
    xh = xin.reshape(bsz, seq, h, s.head_dim)
    bg = b.reshape(bsz, seq, s.ngroups, s.state_dim)
    cg = c.reshape(bsz, seq, s.ngroups, s.state_dim)
    chunk = min(s.chunk, seq)
    if seq % chunk:
        raise ValueError(f"prefill length {seq} not a multiple of chunk {chunk}")
    y, final = ssd_chunked(xh, dt, a, bg, cg, chunk,
                           initial_state=cache["state"])
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = _gated_rmsnorm(y.reshape(bsz, seq, inner), z, p["norm_scale"])
    out = y @ p["out_proj"].astype(u.dtype)
    return out, {"conv": new_conv_cache, "state": final}


def decode_mamba(arch: ArchConfig, p: PyTree, u: jax.Array, cache: PyTree
                 ) -> Tuple[jax.Array, PyTree]:
    """One-token mamba2 step. u [B,1,D]."""
    s = arch.ssm
    bsz = u.shape[0]
    inner = inner_dim(arch)
    h = num_ssm_heads(arch)
    zxbcdt = u[:, 0] @ p["in_proj"].astype(u.dtype)           # [B, proj]
    z, xin, b, c, dt = _split_proj(arch, zxbcdt)
    xbc = jnp.concatenate([xin, b, c], axis=-1)               # [B, C]
    window = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # [B,W,C]
    conv_out = jnp.sum(window.astype(jnp.float32)
                       * p["conv"].astype(jnp.float32)[None], axis=1)
    xbc = silu(conv_out.astype(u.dtype))
    xin, b, c = jnp.split(xbc, [inner, inner + s.ngroups * s.state_dim], axis=-1)
    dt = softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    a = -jnp.exp(p["A_log"])
    y, new_state = ssd_decode_step(
        cache["state"], xin.reshape(bsz, h, s.head_dim), dt, a,
        b.reshape(bsz, s.ngroups, s.state_dim),
        c.reshape(bsz, s.ngroups, s.state_dim))
    y = y + xin.reshape(bsz, h, s.head_dim) * p["D"][None, :, None].astype(y.dtype)
    y = y.reshape(bsz, inner)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = (y @ p["out_proj"].astype(u.dtype))[:, None]
    return out, {"conv": window[:, 1:], "state": new_state}


# --------------------------------------------------- serving decode-state path ----
#
# The continuous engine's per-layer decode-state protocol: a mamba mixer's
# state is NOT page-decomposable (the recurrence folds every past token into
# one [H, N, P] state), so instead of KV pages it declares a *pooled,
# constant-size per-slot* state — ``init_mamba_cache(arch, num_slots, dtype)``
# shapes: conv tail [slot, W-1, C] + SSD state [slot, H, N, P] fp32. A slot
# is recycled by resetting its rows (the ``start == 0`` gate below), and
# preemption is plain forced replay: re-prefilling the victim's context
# recomputes the state, so the resumed stream is token-identical.

def paged_prefill_mamba_layer(arch: ArchConfig, p: PyTree, x: jax.Array,
                              cache: PyTree, slot: jax.Array,
                              start: jax.Array, total_len: jax.Array
                              ) -> Tuple[jax.Array, PyTree]:
    """One prompt chunk of one sequence through a mamba mixer.

    x [1, C, D] — chunk embeddings (row i at absolute position start + i;
    rows past ``total_len - start`` are padding); ``slot`` indexes the
    per-slot state pools. The chunk tail's padding must not perturb the
    recurrent state, and masking it costs nothing extra: a padded position's
    ``dt`` is forced to 0, which makes its state decay exp(dt*a) = 1 (an
    identity pass-through) and its input contribution x*dt = 0 — the SSD
    update over the chunk lands on exactly the state after the last *valid*
    token. ``start == 0`` (fresh admission or forced-replay re-prefill)
    resets the slot's rows, which is all the slot recycling SSM state needs.
    """
    s = arch.ssm
    b, c, _ = x.shape
    assert b == 1, "chunked prefill runs one sequence at a time"
    inner = inner_dim(arch)
    h = num_ssm_heads(arch)
    width = s.conv_width
    zxbcdt = x[0] @ p["in_proj"].astype(x.dtype)             # [C, proj]
    z, xin, bb, cc, dt = _split_proj(arch, zxbcdt)
    xbc = jnp.concatenate([xin, bb, cc], axis=-1)            # [C, Cch]
    continuing = start > 0          # start == 0 -> reset the recycled slot
    conv_tail = jnp.where(continuing, cache["conv"][slot], 0).astype(xbc.dtype)
    state0 = jnp.where(continuing, cache["state"][slot], 0.0)  # [H,N,P] fp32
    ctx = jnp.concatenate([conv_tail, xbc], axis=0)          # [W-1+C, Cch]
    conv_out = jnp.zeros((c, xbc.shape[-1]), jnp.float32)
    for i in range(width):
        conv_out = conv_out + ctx[i:i + c].astype(jnp.float32) * \
            p["conv"][i][None].astype(jnp.float32)
    xbc = silu(conv_out.astype(x.dtype))
    xin, bb, cc = jnp.split(xbc, [inner, inner + s.ngroups * s.state_dim],
                            axis=-1)
    pos = jnp.asarray(start, jnp.int32) + jnp.arange(c, dtype=jnp.int32)
    valid = pos < total_len
    dt = softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    dt = jnp.where(valid[:, None], dt, 0.0)                  # mask padding
    a = -jnp.exp(p["A_log"])
    xh = xin.reshape(1, c, h, s.head_dim)
    # the chunk length is static; gcd keeps the SSD divisibility contract for
    # any page-multiple prefill chunk
    chunk = math.gcd(s.chunk, c)
    y, final = ssd_chunked(xh, dt[None], a,
                           bb.reshape(1, c, s.ngroups, s.state_dim),
                           cc.reshape(1, c, s.ngroups, s.state_dim),
                           chunk, initial_state=state0[None])
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = _gated_rmsnorm(y.reshape(1, c, inner), z[None], p["norm_scale"])
    out = y @ p["out_proj"].astype(x.dtype)
    # conv tail = the W-1 inputs ending at the last valid token: ctx index
    # j >= W-1 is chunk position j-(W-1), so the slice starts at (valid count)
    new_tail = jax.lax.dynamic_slice_in_dim(
        ctx, total_len - start, width - 1, axis=0)
    return out, {
        "conv": cache["conv"].at[slot].set(new_tail.astype(cache["conv"].dtype)),
        "state": cache["state"].at[slot].set(final[0]),
    }


def paged_decode_mamba_layer(arch: ArchConfig, p: PyTree, x: jax.Array,
                             cache: PyTree, active: jax.Array
                             ) -> Tuple[jax.Array, PyTree]:
    """One-token decode over the full slot batch. x [S, 1, D]; cache rows are
    per-slot; ``active`` [S] masks the state update — an inactive slot (empty,
    or mid-prefill and masked out of this decode step) must keep its state:
    unlike KV pages there is no null-page write sink, the state row IS the
    sink, so the engine's fixed-shape step guards it explicitly."""
    y, new = decode_mamba(arch, p, x, cache)
    return y, {
        "conv": jnp.where(active[:, None, None], new["conv"], cache["conv"]),
        "state": jnp.where(active[:, None, None, None], new["state"],
                           cache["state"]),
    }
