import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the step (train_step / prefill_step / serve_step per shape kind),
  2. lowers it with ShapeDtypeStruct inputs under the production mesh,
  3. compiles, prints memory_analysis() (fit proof) and cost_analysis(),
  4. parses the compiled HLO for the collective schedule,
  5. derives the three roofline terms (§Roofline) and appends everything to a
     JSON results file consumed by benchmarks/roofline_table.py & EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import (ASSIGNED, SHAPES, RunConfig, cell_supported, get_config,
                       input_specs)
from ..core import characterize, hlotext, roofline
from ..parallel import sharding as sh
from ..train.steps import build_step
from .mesh import make_production_mesh

RESULTS = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# per-arch run overrides needed to fit / run at scale (documented in DESIGN.md).
# microbatch counts are empirical: the analytic heuristic tracks saved residuals,
# but MoE dispatch / logit-CE transients per microbatch dominate for these archs.
ARCH_OVERRIDES = {
    # 400B: fp32 LAMB states exceed a single 256-chip pod no matter the layout;
    # bf16 m/v (beyond-paper, halves Takeaway-8 traffic) + cross-pod ZeRO on the
    # multi-pod mesh make it fit — see EXPERIMENTS.md §Dry-run.
    "llama4-maverick-400b-a17b": {
        "opt_state_dtype": "bfloat16",
        "sharding_overrides": (("opt_flat", ("data", "model")),),
        "train_microbatches": 8,
    },
    "deepseek-moe-16b": {"train_microbatches": 8},
    "jamba-v0.1-52b": {"train_microbatches": 32},
    "mistral-large-123b": {"train_microbatches": 8},
    "command-r-35b": {"train_microbatches": 4},
}


def default_microbatches(arch, shape, n_devices: int = 256,
                         budget_bytes: float = 2.5e9) -> int:
    """Gradient-accumulation heuristic (paper §4.2).

    Saved residuals per device (seq+batch sharded 256-way, bf16, one per block)
    must fit ``budget_bytes``; more microbatches than that only multiplies FSDP
    weight-gather traffic by the accumulation count.
    """
    if shape.kind != "train":
        return 1
    tokens = shape.global_batch * shape.seq_len
    resid = tokens * max(arch.d_model, 1) * 2 * arch.num_layers / n_devices
    mb = max(1, int(-(-resid // budget_bytes)))
    while shape.global_batch % mb:
        mb += 1
    return min(mb, shape.global_batch)


def make_run(arch_name: str, shape_name: str, **overrides) -> RunConfig:
    arch = get_config(arch_name)
    shape = SHAPES[shape_name]
    merged = dict(ARCH_OVERRIDES.get(arch_name, {}))
    merged.update({k: v for k, v in overrides.items() if v is not None})
    train_mb = merged.pop("train_microbatches", None)
    mb = merged.pop("microbatches", None) or \
        (train_mb if shape.kind == "train" and train_mb else None) or \
        default_microbatches(arch, shape)
    shape = dataclasses.replace(shape, microbatches=mb)
    return RunConfig(arch=arch, shape=shape, **merged)


def struct_tree(f, *args):
    return jax.eval_shape(f, *args)


def lower_cell(run: RunConfig, mesh, rules, donate: bool = True):
    """-> (lowered, compiled, specs_used) for one cell on one mesh."""
    bundle = build_step(run)
    batch = input_specs(run.arch, run.shape)
    if run.sharding_overrides:
        rules = dict(rules)
        for name, axis in run.sharding_overrides:
            rules[name] = axis
    with sh.activate(mesh, rules):
        batch_specs = sh.sanitize_tree(bundle.batch_specs_of(batch), batch)
        batch_shardings = {k: NamedSharding(mesh, s)
                           for k, s in batch_specs.items()}
        if run.shape.kind == "train":
            state = struct_tree(bundle.init)
            specs = sh.sanitize_tree(bundle.state_specs(state), state)
            state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                                    is_leaf=lambda x: isinstance(x, P))
            fn = jax.jit(bundle.fn,
                         in_shardings=(state_sh, batch_shardings),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,) if donate else ())
            lowered = fn.lower(state, batch)
        else:
            params, caches = struct_tree(bundle.init)
            pspecs = sh.sanitize_tree(bundle.param_specs_of(params), params)
            cspecs = sh.sanitize_tree(bundle.cache_specs_of(caches), caches)
            p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                is_leaf=lambda x: isinstance(x, P))
            c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                                is_leaf=lambda x: isinstance(x, P))
            fn = jax.jit(bundle.fn,
                         in_shardings=(p_sh, c_sh, batch_shardings),
                         out_shardings=(None, c_sh),
                         donate_argnums=(1,) if donate else ())
            lowered = fn.lower(params, caches, batch)
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0
    return lowered, compiled, compile_s


def analyze_cell(run: RunConfig, compiled, mesh, compile_s: float) -> dict:
    n_dev = mesh.devices.size
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    # full call-graph cost engine: multiplies while-loop bodies by trip count
    # (XLA's cost_analysis counts scan bodies once — see core/characterize.py)
    cost = characterize.analyze_text(text, n_dev)
    colls = cost.summary()
    terms = roofline.compute_terms(
        flops_per_device=cost.flops, bytes_per_device=cost.bytes,
        colls=colls, n_devices=n_dev, arch=run.arch, shape=run.shape)
    mem = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_bytes": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                       + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        "fits_16gb": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                      + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        <= 16e9,
    }
    # flash-kernel-adjusted memory term: the Pallas flash kernel (validated in
    # tests/test_kernels + test_attention) keeps score tiles in VMEM; its HBM
    # traffic is q/k/v/o (+grads in bwd) only. The chunked stand-in the dry-run
    # lowers pays the tile traffic at HBM — re-price that bucket analytically.
    flash = None
    arch = run.arch
    if arch.num_heads and cost.by_scope_bytes:
        buckets_b = characterize.bucket_scopes(cost.by_scope_bytes)
        attn_bytes = buckets_b.get("attn_bgemm", 0.0)
        n_attn = sum(1 for i in range(arch.num_layers)
                     if arch.is_attention_layer(i))
        tokens = run.shape.global_batch * run.shape.seq_len \
            if run.shape.kind != "decode" else run.shape.global_batch
        passes = 3 if run.shape.kind == "train" else 1
        io = tokens * (2 * arch.q_dim + 2 * arch.kv_dim) * 2
        if run.shape.kind == "decode":
            # decode reads the whole KV cache once per layer
            io += (run.shape.global_batch * run.shape.seq_len
                   * 2 * arch.kv_dim * 2)
        flash_bytes = passes * n_attn * io / n_dev
        mem_flash_s = max(cost.bytes - attn_bytes + flash_bytes, 0.0) \
            / roofline.V5E.hbm_bw
        flash = {"attn_bucket_bytes": attn_bytes,
                 "flash_bytes": flash_bytes,
                 "memory_s": mem_flash_s}
    return {
        "arch": run.arch.name,
        "shape": run.shape.name,
        "kind": run.shape.kind,
        "microbatches": run.shape.microbatches,
        "mesh": {"shape": dict(mesh.shape), "devices": n_dev},
        "compile_s": round(compile_s, 1),
        "memory": mem,
        "flash_adjusted": flash,
        "cost": {"flops_per_device": cost.flops,
                 "bytes_per_device": cost.bytes,
                 "xla_flops_body_once": float(ca.get("flops", 0.0)),
                 "xla_bytes_body_once": float(ca.get("bytes accessed", 0.0))},
        "collectives": colls.to_dict(),
        "op_taxonomy": hlotext.categorize_ops(text),
        "flops_by_category": dict(cost.by_category),
        "bytes_by_category": dict(cost.by_category_bytes),
        "flops_by_bucket": characterize.bucket_scopes(cost.by_scope),
        "bytes_by_bucket": characterize.bucket_scopes(cost.by_scope_bytes),
        "roofline": terms.to_dict(),
    }


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: Path = RESULTS, tag: str = "baseline",
             **overrides) -> dict:
    arch = get_config(arch_name)
    shape = SHAPES[shape_name]
    skip = cell_supported(arch, shape)
    mesh_name = "multi" if multi_pod else "single"
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{tag}__{mesh_name}__{arch_name}__{shape_name}.json"
    if skip:
        rec = {"arch": arch_name, "shape": shape_name, "skip": skip,
               "mesh": mesh_name}
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[dryrun] {arch_name} x {shape_name} ({mesh_name}): {skip}")
        return rec
    run = make_run(arch_name, shape_name, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = sh.make_rules(multi_pod=multi_pod)
    print(f"[dryrun] {arch_name} x {shape_name} ({mesh_name}, "
          f"mb={run.shape.microbatches}) lowering...", flush=True)
    lowered, compiled, compile_s = lower_cell(run, mesh, rules)
    rec = analyze_cell(run, compiled, mesh, compile_s)
    rec["tag"] = tag
    out_path.write_text(json.dumps(rec, indent=1))
    m = rec["memory"]
    r = rec["roofline"]
    print(compiled.memory_analysis())
    print(f"[dryrun] {arch_name} x {shape_name}: compile {compile_s:.0f}s | "
          f"peak/dev {m['peak_bytes']/1e9:.2f} GB (fits16: {m['fits_16gb']}) | "
          f"compute {r['compute_s']*1e3:.1f}ms memory {r['memory_s']*1e3:.1f}ms "
          f"collective {r['collective_s']*1e3:.1f}ms -> {r['dominant']} | "
          f"roofline fraction {r['peak_fraction']:.2f}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for a in ASSIGNED:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells.append((args.arch, args.shape))

    failures = []
    for multi in meshes:
        for a, s in cells:
            try:
                run_cell(a, s, multi, Path(args.out), tag=args.tag,
                         microbatches=args.microbatches)
            except Exception as e:  # noqa: BLE001 — report all cell failures
                traceback.print_exc()
                failures.append((a, s, multi, repr(e)))
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("   ", f)
        raise SystemExit(1)
    print("[dryrun] all requested cells compiled OK")


if __name__ == "__main__":
    main()
