"""Production meshes.

Single pod  : (data=16, model=16)              — 256 chips (one v5e pod).
Multi pod   : (pod=2, data=16, model=16)       — 512 chips; the pod axis carries
              hierarchical data parallelism over DCN.
Defined as functions so importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def _make(shape, axes):
    # jax >= 0.5 takes axis_types (and needs Auto for pjit-style tracing);
    # older releases have neither the kwarg nor jax.sharding.AxisType.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes):
    return _make(shape, axes)


def make_tp_mesh(tp: int):
    """1-D ("model",) mesh over ``tp`` devices — the serving engine's
    tensor-parallel mesh (head-sharded paged KV + Megatron projections).
    CPU CI gets its devices from XLA_FLAGS=--xla_force_host_platform_device_count."""
    n = len(jax.devices())
    assert n >= tp, (
        f"tp={tp} needs {tp} devices, found {n} "
        "(set XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU)")
    return make_mesh((tp,), ("model",))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host (CPU) devices for tests."""
    n = data * model
    assert len(jax.devices()) >= n, (len(jax.devices()), n)
    return make_mesh((data, model), ("data", "model"))
