"""Serving driver with two engines behind ``--engine {static,continuous}``.

static      the original fixed-batch driver: one dense KV cache of
            ``batch * (prompt_len + gen_len)`` rows, every request padded to
            the worst case and decoded in lock-step.
continuous  ``repro.serving.ContinuousEngine``: paged KV cache + scheduler —
            requests are admitted/recycled mid-flight, prompts are ingested
            by chunked prefill, shared prompt prefixes are served from the
            refcounted prefix cache (``--no-prefix-cache`` to disable), and
            live KV memory tracks actual generated lengths.

Both engines are greedy at ``--temperature 0`` and produce identical token
ids for the same prompts (tested in tests/test_serving.py).

``python -m repro.launch.serve --arch llama3.2-3b --smoke --engine continuous``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_config
from ..models import build_model


def _run_static(model, params, args, arch) -> dict:
    b, plen, glen = args.batch, args.prompt_len, args.gen_len
    max_len = plen + glen
    caches = model.init_caches(None, b, max_len)
    prompt = jax.random.randint(jax.random.key(1), (b, plen), 5,
                                arch.vocab_size)
    batch = {"tokens": prompt}
    if arch.family == "encdec":
        batch["frontend_embeddings"] = jax.random.normal(
            jax.random.key(2), (b, arch.enc_seq_len, arch.d_model)
        ).astype(jnp.dtype(arch.dtype))

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, caches = prefill(params, caches, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    tokens = jnp.argmax(logits[:, -1], axis=-1)
    generated = [tokens]
    key = jax.random.key(args.seed + 7)
    t0 = time.perf_counter()
    for i in range(glen - 1):
        db = {"tokens": tokens[:, None],
              "positions": jnp.full((b,), plen + i, jnp.int32)}
        logits, caches = decode(params, caches, db)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tokens = jax.random.categorical(
                sub, logits[:, -1] / args.temperature, axis=-1)
        else:
            tokens = jnp.argmax(logits[:, -1], axis=-1)
        generated.append(tokens)
    jax.block_until_ready(generated[-1])
    t_decode = time.perf_counter() - t0

    out = np.stack([np.asarray(t) for t in generated], axis=1)
    print(f"[serve/static] {arch.name}: prefill {plen} tok x{b} in "
          f"{t_prefill*1e3:.1f}ms | {glen} decode steps in "
          f"{t_decode*1e3:.1f}ms ({t_decode/max(glen-1,1)*1e3:.1f} ms/tok)")
    print(f"[serve/static] sample generations (first 8 ids/row): "
          f"{out[:2, :8].tolist()}")
    return {"tokens": out, "t_prefill": t_prefill, "t_decode": t_decode}


def _run_continuous(model, params, args, arch) -> dict:
    from ..serving import ContinuousEngine, Request, pages_needed

    b, plen, glen = args.batch, args.prompt_len, args.gen_len
    assert args.temperature == 0, "continuous engine is greedy-only for now"
    prompt = np.asarray(jax.random.randint(jax.random.key(1), (b, plen), 5,
                                           arch.vocab_size))
    max_seq = plen + glen
    num_pages = args.num_pages or (
        b * pages_needed(max_seq + 1, args.page_size) + 2)
    engine = ContinuousEngine(model, params, num_slots=args.slots or b,
                              num_pages=num_pages, page_size=args.page_size,
                              max_seq_len=max_seq + args.page_size,
                              prefix_cache=args.prefix_cache,
                              prefill_chunk=args.prefill_chunk or None)
    reqs = [Request(uid=i, prompt=[int(t) for t in prompt[i]],
                    max_new_tokens=glen) for i in range(b)]
    t0 = time.perf_counter()
    results = engine.run(reqs)
    wall = time.perf_counter() - t0
    out = np.stack([np.asarray(results[i]["tokens"]) for i in range(b)])
    total_tokens = out.size
    print(f"[serve/continuous] {arch.name}: {b} requests x {glen} tokens in "
          f"{wall*1e3:.1f}ms ({total_tokens/wall:.1f} tok/s, "
          f"{engine.steps} decode steps, {engine.prefills} prefills, "
          f"{engine.prefill_tokens} prompt tokens computed / "
          f"{engine.cached_prefill_tokens} from prefix cache)")
    print(f"[serve/continuous] sample generations (first 8 ids/row): "
          f"{out[:2, :8].tolist()}")
    return {"tokens": out, "wall": wall, "steps": engine.steps,
            "prefills": engine.prefills,
            "prefill_tokens": engine.prefill_tokens,
            "cached_prefill_tokens": engine.cached_prefill_tokens}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=("static", "continuous"),
                    default="static")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # continuous-engine knobs
    ap.add_argument("--slots", type=int, default=0,
                    help="decode slots (default: --batch)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV pool pages (default: sized to the request set)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="share cached prompt-prefix pages across requests "
                         "(--no-prefix-cache to disable)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill tokens per step, a page multiple "
                         "(default: 4 pages)")
    args = ap.parse_args(argv)

    arch = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    assert not arch.bidirectional, "encoder-only archs have no decode step"
    model = build_model(arch)
    params = model.init(jax.random.key(args.seed))
    params = jax.tree.map(lambda p: p.astype(jnp.dtype(arch.dtype)), params)

    if args.engine == "continuous":
        return _run_continuous(model, params, args, arch)
    return _run_static(model, params, args, arch)


if __name__ == "__main__":
    main()
