"""Serving driver with two engines behind ``--engine {static,continuous}``.

static      the original fixed-batch driver: one dense KV cache of
            ``batch * (prompt_len + gen_len)`` rows, every request padded to
            the worst case and decoded in lock-step.
continuous  ``repro.serving.ContinuousEngine``: paged KV cache + scheduler —
            requests are admitted/recycled mid-flight, prompts are ingested
            by chunked prefill, shared prompt prefixes are served from the
            refcounted prefix cache (``--no-prefix-cache`` to disable), and
            live KV memory tracks actual generated lengths. ``--decode-steps
            N`` moves N decode iterations into one compiled on-device loop
            per host dispatch (token streams stay bit-identical to N=1).
            Serves every
            decode-state-protocol family — dense, MoE, VLM, pure-SSM
            (mamba2), hybrid (jamba) — with prefix caching auto-gated off
            for SSM-bearing archs (recurrent state is not page-decomposable;
            an explicit ``--prefix-cache`` is rejected up front).

Sampling (``--temperature/--top-k/--top-p/--seed``) is valid for BOTH
engines: request ``i`` gets ``SamplingParams(seed = --seed + i)`` and both
paths draw from the shared ``repro.serving.sampling`` sampler, whose PRNG
key is ``fold_in(key(seed), position)`` — so the two engines emit identical
token ids for the same prompts at any temperature, not just greedy
(tested in tests/test_serving.py and tests/test_sampling.py).

``python -m repro.launch.serve --arch llama3.2-3b --smoke --engine continuous``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_config
from ..models import build_model
from ..serving.sampling import (SamplingParams, fused_sampling_enabled,
                                sample_tokens)


def _fused(args) -> bool:
    """--sampler beats the REPRO_FUSED_SAMPLING env default."""
    if args.sampler is not None:
        return args.sampler == "fused"
    return fused_sampling_enabled()


def _request_seed(args, i: int) -> int:
    """Request i is seeded ``--seed + i`` (mod 2^32 — the sampler's key
    width) in BOTH engines, which is what makes their streams comparable."""
    return (args.seed + i) % (2 ** 32)


def _sampling_arrays(args, batch):
    """Per-request sampler inputs for the static path."""
    return (jnp.asarray([_request_seed(args, i) for i in range(batch)],
                        jnp.uint32),
            jnp.full((batch,), args.temperature, jnp.float32),
            jnp.full((batch,), args.top_k, jnp.int32),
            jnp.full((batch,), args.top_p, jnp.float32))


def _run_static(model, params, args, arch) -> dict:
    b, plen, glen = args.batch, args.prompt_len, args.gen_len
    max_len = plen + glen
    caches = model.init_caches(None, b, max_len)
    prompt = jax.random.randint(jax.random.key(1), (b, plen), 5,
                                arch.vocab_size)
    batch = {"tokens": prompt}
    if arch.family == "encdec":
        batch["frontend_embeddings"] = jax.random.normal(
            jax.random.key(2), (b, arch.enc_seq_len, arch.d_model)
        ).astype(jnp.dtype(arch.dtype))

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    if args.temperature > 0:
        filtered = args.top_k > 0 or args.top_p < 1.0
        fused = _fused(args) and filtered
        sample = jax.jit(sample_tokens,
                         static_argnames=("filtered", "fused"))
        seeds, temps, top_ks, top_ps = _sampling_arrays(args, b)

        def pick(logits, pos):
            # the sampler folds each request's stream position into its key,
            # matching the continuous engine draw for draw
            return sample(logits, seeds, jnp.full((b,), pos, jnp.int32),
                          temps, top_ks, top_ps, filtered=filtered,
                          fused=fused)
    else:
        # greedy stays a pure argmax — no sampler sorts/keys on the default
        # path (bit-identical by the sampler's temperature-0 contract, and
        # the same specialization the continuous engine's static flag does)
        def pick(logits, pos):
            return jnp.argmax(logits, axis=-1)

    t0 = time.perf_counter()
    logits, caches = prefill(params, caches, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    # the prompt's next token sits at stream position plen; each decode step
    # i then emits position plen + 1 + i
    tokens = pick(logits[:, -1], plen)
    generated = [tokens]
    t0 = time.perf_counter()
    for i in range(glen - 1):
        db = {"tokens": tokens[:, None],
              "positions": jnp.full((b,), plen + i, jnp.int32)}
        logits, caches = decode(params, caches, db)
        tokens = pick(logits[:, -1], plen + 1 + i)
        generated.append(tokens)
    jax.block_until_ready(generated[-1])
    t_decode = time.perf_counter() - t0

    out = np.stack([np.asarray(t) for t in generated], axis=1)
    print(f"[serve/static] {arch.name}: prefill {plen} tok x{b} in "
          f"{t_prefill*1e3:.1f}ms | {glen} decode steps in "
          f"{t_decode*1e3:.1f}ms ({t_decode/max(glen-1,1)*1e3:.1f} ms/tok)")
    print(f"[serve/static] sample generations (first 8 ids/row): "
          f"{out[:2, :8].tolist()}")
    return {"tokens": out, "t_prefill": t_prefill, "t_decode": t_decode}


def _run_continuous(model, params, args, arch) -> dict:
    from ..serving import ContinuousEngine, Request, pages_needed

    b, plen, glen = args.batch, args.prompt_len, args.gen_len
    prompt = np.asarray(jax.random.randint(jax.random.key(1), (b, plen), 5,
                                           arch.vocab_size))
    max_seq = plen + glen
    num_pages = args.num_pages or (
        b * pages_needed(max_seq + 1, args.page_size) + 2)
    engine = ContinuousEngine(model, params, num_slots=args.slots or b,
                              num_pages=num_pages, page_size=args.page_size,
                              max_seq_len=max_seq + args.page_size,
                              prefix_cache=args.prefix_cache,
                              prefill_chunk=args.prefill_chunk or None,
                              tp=args.tp, fused_sampling=_fused(args),
                              decode_steps=args.decode_steps,
                              fused_decode=args.fused_decode)
    reqs = [Request(uid=i, prompt=[int(t) for t in prompt[i]],
                    max_new_tokens=glen,
                    sampling=SamplingParams(temperature=args.temperature,
                                            top_k=args.top_k,
                                            top_p=args.top_p,
                                            seed=_request_seed(args, i)))
            for i in range(b)]
    t0 = time.perf_counter()
    results = engine.run(reqs)
    wall = time.perf_counter() - t0
    out = np.stack([np.asarray(results[i]["tokens"]) for i in range(b)])
    total_tokens = out.size
    print(f"[serve/continuous] {arch.name}: {b} requests x {glen} tokens in "
          f"{wall*1e3:.1f}ms ({total_tokens/wall:.1f} tok/s, "
          f"{engine.steps} decode steps, {engine.prefills} prefills, "
          f"{engine.prefill_tokens} prompt tokens computed / "
          f"{engine.cached_prefill_tokens} from prefix cache)")
    print(f"[serve/continuous] sample generations (first 8 ids/row): "
          f"{out[:2, :8].tolist()}")
    stats = {"tokens": out, "wall": wall, "steps": engine.steps,
             "prefills": engine.prefills,
             "decode_dispatches": engine.decode_dispatches,
             "decode_exits": dict(engine.decode_exits),
             "prefill_tokens": engine.prefill_tokens,
             "cached_prefill_tokens": engine.cached_prefill_tokens,
             "prefix_cache_off_reason": engine.prefix_cache_off_reason}
    if args.decode_steps > 1:
        print(f"[serve/continuous] decode-steps={args.decode_steps}: "
              f"{engine.decode_dispatches} host dispatches for "
              f"{engine.steps} decode steps "
              f"(exits: {dict(engine.decode_exits)})")
    if engine.prefix_cache_off_reason:
        print(f"[serve/continuous] {engine.prefix_cache_off_reason}")
    if engine.fused_decode_off_reason:
        print(f"[serve/continuous] {engine.fused_decode_off_reason}")
    stats["fused_decode"] = engine.fused_decode
    stats["fused_decode_off_reason"] = engine.fused_decode_off_reason
    if args.tp > 1:
        tps = engine.tp_stats()
        print(f"[serve/continuous] tp={args.tp}: "
              f"{tps['collective_bytes_per_device'] / 1e6:.2f} MB "
              f"all-reduced per device, "
              f"{tps['per_device']['kv_bytes'] / 1e6:.2f} MB KV per device "
              f"({tps['per_device']['pages_in_use']} pages, head-sharded)")
        stats["tp_stats"] = tps
    return stats


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=("static", "continuous"),
                    default="static")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    # sampling (both engines; request i is seeded --seed + i)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy argmax; > 0 scales logits before the "
                         "categorical draw")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest logits (0 = disabled)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass in (0, 1] (1.0 = disabled)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base PRNG seed: params init + per-request "
                         "sampling seeds (--seed + request index)")
    ap.add_argument("--sampler", choices=("fused", "ref"), default=None,
                    help="top-k/top-p filter implementation: the sort-free "
                         "streaming kernel (default) or the sort-based "
                         "reference. Token streams are bit-identical; 'ref' "
                         "is a fallback/debugging path (default from "
                         "REPRO_FUSED_SAMPLING, unset = fused)")
    # continuous-engine knobs
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree over a 1-D device mesh "
                         "(continuous engine only; must divide the query "
                         "heads and either divide or be a multiple of the "
                         "KV heads — the latter replicates KV shards; MoE "
                         "experts shard expert-parallel; on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count)")
    ap.add_argument("--slots", type=int, default=0,
                    help="decode slots (default: --batch)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV pool pages (default: sized to the request set)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="share cached prompt-prefix pages across requests "
                         "(default: on for attention-only archs; forced off "
                         "for SSM-bearing archs, whose recurrent decode "
                         "state is not page-decomposable)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill tokens per step, a page multiple "
                         "(default: 4 pages)")
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="decode iterations per host dispatch: N > 1 runs a "
                         "compiled on-device loop that early-exits on "
                         "EOS/budget/page exhaustion, cutting host syncs by "
                         "~N while keeping token streams bit-identical "
                         "(continuous engine only)")
    ap.add_argument("--fused-decode", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="fused decode residual stream + streaming LM-head "
                         "epilogue (no [S, V] logits buffer; token streams "
                         "bit-identical either way). Default from "
                         "REPRO_FUSED_DECODE (unset = on); auto-falls back "
                         "with a recorded reason for post-norm stacks, MLM "
                         "heads, and non-tile-aligned TP vocab shards "
                         "(continuous engine only)")
    args = ap.parse_args(argv)
    # one validation for BOTH engines (the static path reads raw args, so
    # without this it would silently reinterpret e.g. --top-p 0)
    try:
        sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                            top_p=args.top_p, seed=args.seed)
    except ValueError as e:
        ap.error(str(e))
    if sp.greedy and sp.filtered:
        ap.error("--top-k/--top-p have no effect at --temperature 0 "
                 "(greedy argmax); set --temperature > 0 to sample")
    if args.tp > 1 and args.engine != "continuous":
        ap.error("--tp requires --engine continuous")
    if args.decode_steps < 1:
        ap.error("--decode-steps must be >= 1")
    if args.decode_steps > 1 and args.engine != "continuous":
        ap.error("--decode-steps requires --engine continuous (the static "
                 "driver decodes in lock-step, one token per dispatch)")
    if args.fused_decode is not None and args.engine != "continuous":
        ap.error("--fused-decode requires --engine continuous (the static "
                 "driver always materializes full logits)")

    arch = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    assert not arch.bidirectional, "encoder-only archs have no decode step"
    if args.engine == "continuous":
        from ..serving.engine import SERVABLE_FAMILIES
        if arch.family not in SERVABLE_FAMILIES:
            ap.error(f"--engine continuous serves families "
                     f"{SERVABLE_FAMILIES}; {arch.name} is {arch.family!r} "
                     "(use --engine static)")
    # an EXPLICIT --prefix-cache on an SSM-bearing arch fails here with the
    # reason, not as an assertion deep in the engine (the static engine has
    # no prefix cache; the flag only gates continuous). The default stays
    # True so the engine itself performs the SSM gate and records the
    # reason in every result — resolving it to False here would skip that
    # marker and turn the gate into the silent no-op it must never be.
    if args.prefix_cache and arch.family in ("ssm", "hybrid") \
            and args.engine == "continuous":
        ap.error(f"--prefix-cache is unsupported for {arch.family} archs "
                 f"({arch.name}): SSM recurrent decode state is not "
                 "page-decomposable, so cached KV pages cannot be shared; "
                 "rerun without --prefix-cache")
    if args.prefix_cache is None:
        args.prefix_cache = True
    model = build_model(arch)
    params = model.init(jax.random.key(args.seed))
    params = jax.tree.map(lambda p: p.astype(jnp.dtype(arch.dtype)), params)

    if args.engine == "continuous":
        return _run_continuous(model, params, args, arch)
    return _run_static(model, params, args, arch)


if __name__ == "__main__":
    main()
