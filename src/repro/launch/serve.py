"""Batched serving driver: prefill a prompt batch, then decode tokens.

``python -m repro.launch.serve --arch llama3.2-3b --smoke --batch 4 --prompt-len 32``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_config
from ..models import build_model


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    assert not arch.bidirectional, "encoder-only archs have no decode step"
    model = build_model(arch)
    params = model.init(jax.random.key(args.seed))
    params = jax.tree.map(lambda p: p.astype(jnp.dtype(arch.dtype)), params)

    b, plen, glen = args.batch, args.prompt_len, args.gen_len
    max_len = plen + glen
    caches = model.init_caches(None, b, max_len)
    prompt = jax.random.randint(jax.random.key(1), (b, plen), 5,
                                arch.vocab_size)
    batch = {"tokens": prompt}
    if arch.family == "encdec":
        batch["frontend_embeddings"] = jax.random.normal(
            jax.random.key(2), (b, arch.enc_seq_len, arch.d_model)
        ).astype(jnp.dtype(arch.dtype))

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, caches = prefill(params, caches, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    tokens = jnp.argmax(logits[:, -1], axis=-1)
    generated = [tokens]
    key = jax.random.key(args.seed + 7)
    t0 = time.perf_counter()
    for i in range(glen - 1):
        db = {"tokens": tokens[:, None],
              "positions": jnp.full((b,), plen + i, jnp.int32)}
        logits, caches = decode(params, caches, db)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tokens = jax.random.categorical(
                sub, logits[:, -1] / args.temperature, axis=-1)
        else:
            tokens = jnp.argmax(logits[:, -1], axis=-1)
        generated.append(tokens)
    jax.block_until_ready(generated[-1])
    t_decode = time.perf_counter() - t0

    out = np.stack([np.asarray(t) for t in generated], axis=1)
    print(f"[serve] {arch.name}: prefill {plen} tok x{b} in "
          f"{t_prefill*1e3:.1f}ms | {glen} decode steps in "
          f"{t_decode*1e3:.1f}ms ({t_decode/max(glen-1,1)*1e3:.1f} ms/tok)")
    print(f"[serve] sample generations (first 8 ids/row): "
          f"{out[:2, :8].tolist()}")
    return {"tokens": out, "t_prefill": t_prefill, "t_decode": t_decode}


if __name__ == "__main__":
    main()
