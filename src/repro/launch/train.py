"""End-to-end trainer: ``python -m repro.launch.train --arch <id> [...]``.

Runs real optimization (synthetic data) on whatever devices exist — one CPU for
the examples/tests, a real mesh in production. Auto-resumes from the newest
checkpoint, demonstrating the crash/restart contract (tests kill/restart this
under the fault-tolerance suite).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from ..configs import RunConfig, ShapeConfig, get_config, smoke_config
from ..data import DataConfig, SyntheticPipeline
from ..checkpoint import CheckpointManager
from ..train.loop import LoopConfig, train_loop
from ..train.steps import build_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bert-large")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="lamb")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--no-master-weights", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train", microbatches=args.microbatches)
    run = RunConfig(arch=arch, shape=shape, optimizer=args.optimizer,
                    learning_rate=args.lr, zero1=False,
                    master_weights=not args.no_master_weights,
                    seed=args.seed)
    bundle = build_train_step(run)
    step_fn = jax.jit(bundle.fn, donate_argnums=(0,))

    objective = "mlm" if arch.bidirectional else "causal"
    data = SyntheticPipeline(DataConfig(
        vocab_size=arch.vocab_size, seq_len=args.seq,
        global_batch=args.batch, objective=objective, seed=args.seed))

    start_step = 0
    state = None
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and ckpt.latest_step() is not None:
        restored = ckpt.restore()
        state = jax.tree.map(jax.numpy.asarray, restored["state"])
        start_step = restored["extra"].get("data_step", restored["step"])
        print(f"[train] resumed from step {start_step}")
    if state is None:
        state = bundle.init(args.seed)

    loop_cfg = LoopConfig(max_steps=args.steps, ckpt_every=args.ckpt_every,
                          log_every=max(args.steps // 20, 1))
    out = train_loop(step_fn, state, data, loop_cfg,
                     start_step=start_step, ckpt=ckpt)
    losses = [h["loss"] for h in out["history"]]
    if losses:
        print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"over {len(losses)} steps "
              f"(stragglers: {out['monitor'].stragglers})")
    return out


if __name__ == "__main__":
    main()
