"""Fault-tolerant training loop: checkpoint/restart, watchdog, straggler monitor.

SPMD reality at 1000+ nodes: a straggling or hung worker stalls the whole step.
The mitigations a framework can provide (DESIGN.md §7) are (a) detecting it —
the per-step EWMA monitor flags steps >> the running mean, and the watchdog
aborts the process on a hard deadline so the cluster scheduler can restart it;
(b) making restarts cheap — frequent async checkpoints plus elastic restore
(the checkpoint re-shards onto whatever mesh the restarted job gets).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..data import DataConfig, SyntheticPipeline


@dataclasses.dataclass
class LoopConfig:
    max_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep: int = 3
    log_every: int = 10
    # watchdog: abort if a step exceeds this wall-time (0 = disabled)
    step_deadline_s: float = 0.0
    # straggler flagging: step > factor * EWMA
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1


class StepMonitor:
    """EWMA step-time tracker + hard-deadline watchdog."""

    def __init__(self, cfg: LoopConfig, on_deadline: Callable[[], None]):
        self.cfg = cfg
        self.ewma: Optional[float] = None
        self.stragglers = 0
        self._deadline_timer: Optional[threading.Timer] = None
        self._on_deadline = on_deadline

    def step_started(self) -> None:
        if self.cfg.step_deadline_s > 0:
            self._deadline_timer = threading.Timer(
                self.cfg.step_deadline_s, self._on_deadline)
            self._deadline_timer.daemon = True
            self._deadline_timer.start()

    def step_finished(self, dt: float) -> bool:
        """-> True if this step was a straggler."""
        if self._deadline_timer is not None:
            self._deadline_timer.cancel()
            self._deadline_timer = None
        straggler = (self.ewma is not None
                     and dt > self.cfg.straggler_factor * self.ewma)
        a = self.cfg.ewma_alpha
        self.ewma = dt if self.ewma is None else (1 - a) * self.ewma + a * dt
        if straggler:
            self.stragglers += 1
        return straggler


def train_loop(step_fn: Callable, state: Any, data: SyntheticPipeline,
               cfg: LoopConfig,
               start_step: int = 0,
               ckpt: Optional[CheckpointManager] = None,
               log: Callable[[str], None] = print) -> Dict[str, Any]:
    """Run (or resume) training; returns {"state", "history", "monitor"}."""
    if ckpt is None and cfg.ckpt_dir:
        ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)

    def _abort():
        log("[watchdog] step deadline exceeded — checkpointing impossible "
            "mid-step; aborting for scheduler restart")
        import os
        os._exit(42)

    monitor = StepMonitor(cfg, _abort)
    history = []
    # metrics stay ON DEVICE for one step: float()-ing the CURRENT step's
    # metrics forces a host sync that serializes async dispatch (the device
    # drains before the next step is enqueued). Instead each step syncs on
    # the PREVIOUS step's metrics — the device always has this step queued
    # behind the wait, so dispatch stays async, while dt still measures real
    # device step time (attributed one step late) and the straggler EWMA and
    # deadline watchdog keep watching actual compute, not dispatch.
    pending = []                        # (history index, device metrics)

    def _materialize(upto=None):
        while pending and (upto is None or pending[0][0] <= upto):
            idx, m = pending.pop(0)
            history[idx].update(
                jax.tree.map(lambda x: float(np.asarray(x)), m))

    it = data.iterator(start_step=start_step)
    for step in range(start_step, cfg.max_steps):
        batch = next(it)
        monitor.step_started()
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        history.append({"step": step, "dt": 0.0})
        pending.append((len(history) - 1, metrics))
        _materialize(upto=len(history) - 2)   # pipeline-depth-1 sync
        dt = time.perf_counter() - t0
        history[-1]["dt"] = dt
        straggler = monitor.step_finished(dt)
        if straggler:
            log(f"[monitor] step {step} straggled: {dt:.3f}s vs EWMA "
                f"{monitor.ewma:.3f}s")
        if step % cfg.log_every == 0 or straggler:
            # log the newest COMPLETED step: flushing the in-flight one here
            # would leave the next step nothing to wait on, so its dt would
            # time bare dispatch and skew the straggler EWMA every interval
            if len(history) == 1:
                _materialize()          # very first line: one-time sync
            done = history[-1] if len(history) == 1 else history[-2]
            log(f"step {done['step']:5d} "
                f"loss={done.get('loss', float('nan')):.4f} "
                f"acc={done.get('accuracy', 0.0):.3f} "
                f"{done['dt']*1e3:.0f}ms")
        if ckpt and (step + 1) % cfg.ckpt_every == 0:
            ckpt.save_async(step + 1, state,
                            extra={"data_step": step + 1})
    _materialize()
    if ckpt:
        ckpt.wait()
        ckpt.save(cfg.max_steps, state, extra={"data_step": cfg.max_steps})
    return {"state": state, "history": history, "monitor": monitor}
