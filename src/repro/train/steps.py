"""train_step / prefill_step / serve_step builders with full sharding metadata.

``build_train_step`` returns (step_fn, state_init_fn, shardings) so both the real
trainer (launch/train.py) and the dry-run (launch/dryrun.py) consume the same code:
the dry-run lowers ``step_fn`` with ShapeDtypeStructs, the trainer jits it with
donated state.

Mixed precision (paper §3.2.1): master params fp32; compute casts to ``arch.dtype``
(bf16 — the TPU adaptation of the paper's fp16+master-copy scheme, no loss scaling
needed); LAMB runs in fp32 exactly as the paper's "updates remain FP32" observation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, RunConfig, ShapeConfig
from ..models import build_model
from ..optim import grad as grad_lib
from ..optim import make_optimizer
from ..parallel import sharding as sh

PyTree = Any


@dataclasses.dataclass
class StepBundle:
    """Everything needed to run or lower one step kind."""
    fn: Callable                      # (state, batch) -> (state, metrics) | serve sig
    init: Callable                    # () -> state (on-device, sharded)
    state_specs: PyTree               # PartitionSpec pytree for state
    batch_specs: Dict[str, P]         # PartitionSpec per batch input
    donate: Tuple[int, ...] = (0,)


# ----------------------------------------------------------------------- train ----

def build_train_step(run: RunConfig) -> StepBundle:
    arch, shape = run.arch, run.shape
    model = build_model(arch, fuse_qkv=run.fuse_qkv)
    opt = make_optimizer(run)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def step(state: PyTree, batch: Dict[str, jax.Array]):
        params = state["params"]
        transform = None
        if run.zero1 and run.optimizer in ("lamb", "adamw"):
            # accumulate grads directly in the ZeRO flat/sharded layout:
            # the fp32 carry is 1/(D*M) per device (ZeRO-2-style)
            from ..optim import lamb as lamb_lib
            from ..optim import zero as zero_lib
            la = lamb_lib._layer_axes(params) if run.optimizer == "lamb" \
                else jax.tree.map(lambda _: 0, params)

            def transform(g):  # noqa: F811
                flat = jax.tree.map(
                    lambda x, z: zero_lib.flatten_leaf(x, z, 256), g, la)
                return sh.constrain_flat(flat)

        grads, metrics = grad_lib.accumulate_microbatches(
            loss_fn, params, batch, shape.microbatches, transform=transform)
        if run.grad_clip > 0:
            grads, gnorm = grad_lib.clip_by_global_norm(grads, run.grad_clip)
            metrics = dict(metrics, grad_norm=gnorm)
        new_params, new_opt = opt.update(grads, state["opt"], params)
        return {"params": new_params, "opt": new_opt}, metrics

    def init(seed: int = 0):
        params = model.init(jax.random.key(seed))
        if run.master_weights:
            # bf16 params in the model; the optimizer holds the fp32 master copy
            # (paper §3.2.1 mixed precision) — this also halves FSDP traffic.
            state = {"opt": opt.init(params)}
            state["params"] = jax.tree.map(
                lambda p: p.astype(jnp.dtype(arch.dtype)), params)
            return state
        return {"params": params, "opt": opt.init(params)}

    def state_specs_of(state):
        pspecs = sh.param_pspecs(state["params"])
        return {"params": pspecs,
                "opt": sh.opt_state_pspecs(state["opt"], pspecs, run.zero1)}

    bundle = StepBundle(fn=step, init=init, state_specs=state_specs_of,
                        batch_specs=None)
    bundle.batch_specs_of = sh.batch_pspecs
    return bundle


# ----------------------------------------------------------------------- serve ----

def _serve_params(model, arch: ArchConfig, seed: int) -> PyTree:
    """Serving uses inference-dtype (bf16) checkpoints."""
    params = model.init(jax.random.key(seed))
    return jax.tree.map(lambda p: p.astype(jnp.dtype(arch.dtype)), params)


def build_prefill_step(run: RunConfig) -> StepBundle:
    arch, shape = run.arch, run.shape
    model = build_model(arch, fuse_qkv=run.fuse_qkv)

    def step(params: PyTree, caches: PyTree, batch: Dict[str, jax.Array]):
        return model.prefill(params, caches, batch)

    def init(seed: int = 0):
        params = _serve_params(model, arch, seed)
        caches = model.init_caches(None, shape.global_batch, shape.seq_len)
        return params, caches

    bundle = StepBundle(fn=step, init=init, state_specs=None, batch_specs=None,
                        donate=(1,))
    bundle.param_specs_of = sh.param_pspecs
    bundle.cache_specs_of = sh.cache_pspecs
    bundle.batch_specs_of = sh.batch_pspecs
    return bundle


def build_serve_step(run: RunConfig) -> StepBundle:
    """decode_* cells: one new token against a seq_len KV cache."""
    arch, shape = run.arch, run.shape
    model = build_model(arch, fuse_qkv=run.fuse_qkv)

    def step(params: PyTree, caches: PyTree, batch: Dict[str, jax.Array]):
        return model.decode_step(params, caches, batch)

    def init(seed: int = 0):
        params = _serve_params(model, arch, seed)
        caches = model.init_caches(None, shape.global_batch, shape.seq_len)
        return params, caches

    bundle = StepBundle(fn=step, init=init, state_specs=None, batch_specs=None,
                        donate=(1,))
    bundle.param_specs_of = sh.param_pspecs
    bundle.cache_specs_of = sh.cache_pspecs
    bundle.batch_specs_of = sh.batch_pspecs
    return bundle


def build_step(run: RunConfig) -> StepBundle:
    kind = run.shape.kind
    if kind == "train":
        return build_train_step(run)
    if kind == "prefill":
        return build_prefill_step(run)
    if kind == "decode":
        return build_serve_step(run)
    raise ValueError(kind)
