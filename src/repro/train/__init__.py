from .steps import StepBundle, build_prefill_step, build_serve_step, \
    build_step, build_train_step

__all__ = ["StepBundle", "build_step", "build_train_step",
           "build_prefill_step", "build_serve_step"]
