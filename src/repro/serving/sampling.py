"""Per-request stochastic decoding for the serving engines.

``SamplingParams`` rides on every ``Request``; ``sample_tokens`` is the one
on-device sampler both engines share (the continuous engine's decode step and
final prefill chunk, and the static driver in ``repro.launch.serve``), so a
fixed per-request seed yields the identical token stream no matter which
engine served it.

Determinism contract
--------------------
The PRNG key for the token emitted at stream position ``p`` (0-indexed over
prompt + generated tokens) of a request with seed ``s`` is::

    fold_in(key(s), p)

It depends on nothing else — not the decode slot the request landed in, not
which neighbours share the batch, not whether the token came from a decode
step or the final chunk of a (re-)prefill. That last property is what makes
recompute-preemption *forced replay*: a preempted sequence re-prefills
prompt + generated-so-far as forced context (no token is ever re-decided),
and the next token it samples uses the same ``(seed, position)`` key the
uninterrupted run would have used, so resumed sequences are token-identical
under any sampling setting.

Filtering order follows the common serving convention: temperature scaling,
then top-k, then top-p (nucleus) on the rescaled distribution, then one
categorical draw. ``temperature == 0`` short-circuits to raw ``argmax`` on
the unscaled logits — bit-identical to the historical greedy path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How one request's tokens are chosen.

    temperature  0 = greedy argmax (the default; exact static/continuous
                 parity). > 0 divides the logits before the softmax draw.
    top_k        keep only the k highest logits (0 = disabled).
    top_p        keep the smallest set of tokens whose probability mass
                 reaches top_p (nucleus sampling; 1.0 = disabled).
    seed         per-request PRNG seed; the draw for stream position p uses
                 fold_in(key(seed), p), nothing else.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0: {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables): {self.top_k}")
        if not 0 < self.top_p <= 1:
            raise ValueError(f"top_p must be in (0, 1]: {self.top_p}")
        if not 0 <= self.seed < 2 ** 32:
            raise ValueError(f"seed must fit in uint32: {self.seed}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0

    @property
    def filtered(self) -> bool:
        """True when top-k or top-p actually constrains the distribution —
        the engines skip the sampler's [B, V] sorts entirely otherwise."""
        return self.top_k > 0 or self.top_p < 1.0


def sample_tokens(logits: jax.Array, seeds: jax.Array, positions: jax.Array,
                  temperatures: jax.Array, top_k: jax.Array,
                  top_p: jax.Array, *, filtered: bool = True) -> jax.Array:
    """Draw one token per row of ``logits`` [B, V] -> int32 [B].

    All parameter arrays are per-row [B]: ``seeds`` uint32, ``positions``
    int32 (the stream position of the token being emitted), ``temperatures``
    / ``top_p`` float32, ``top_k`` int32 (0 = disabled). Rows with
    ``temperature == 0`` return ``argmax(logits)`` on the raw logits —
    bit-identical to the greedy path — and their PRNG work is discarded.

    ``filtered`` is a static (Python) flag: pass False when every row has
    top_k and top_p disabled to skip the two [B, V] sorts (top-k threshold,
    nucleus cutoff) entirely — for finite logits the disabled filters are
    exact no-ops, so both variants draw the identical token for the same
    (seed, position, logits). Traceable/jittable either way; nothing bigger
    than the [B] token vector ever crosses to the host.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    vocab = logits.shape[-1]
    temps = temperatures.astype(jnp.float32)
    safe_t = jnp.where(temps > 0, temps, 1.0)
    lg = logits.astype(jnp.float32) / safe_t[:, None]

    if filtered:
        # top-k: mask everything below the kth-largest rescaled logit
        k = jnp.where(top_k <= 0, vocab, jnp.minimum(top_k, vocab))
        kth = jnp.take_along_axis(jnp.sort(lg, axis=-1),
                                  (vocab - k)[:, None], axis=-1)
        lg = jnp.where(lg < kth, -jnp.inf, lg)

        # top-p: keep the smallest descending-prob prefix reaching top_p.
        # A disabled row (top_p >= 1) keeps everything EXPLICITLY: float32
        # cumsum can reach 1.0 before the last token, and `cum - probs < 1`
        # alone would then mask real tail tokens only in this variant,
        # making the draw depend on which co-batched neighbour forced the
        # filtered path
        desc = jnp.sort(lg, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(desc, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        tp = top_p.astype(jnp.float32)[:, None]
        keep = ((cum - probs) < tp) | (tp >= 1.0)
        # last kept rank; the clamp keeps an out-of-contract top_p <= 0
        # (callers validate via SamplingParams) at "top-1" instead of
        # wrapping -1 to the weakest logit and silently disabling the filter
        cutoff = jnp.maximum(jnp.sum(keep, axis=-1) - 1, 0)
        thresh = jnp.take_along_axis(desc, cutoff[:, None], axis=-1)
        lg = jnp.where(lg < thresh, -jnp.inf, lg)

    keys = jax.vmap(
        lambda s, p: jax.random.fold_in(jax.random.key(s), p)
    )(seeds.astype(jnp.uint32), positions.astype(jnp.int32))
    sampled = jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)
