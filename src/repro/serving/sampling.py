"""Per-request stochastic decoding for the serving engines.

``SamplingParams`` rides on every ``Request``; ``sample_tokens`` is the one
on-device sampler both engines share (the continuous engine's decode step and
final prefill chunk, and the static driver in ``repro.launch.serve``), so a
fixed per-request seed yields the identical token stream no matter which
engine served it.

Determinism contract
--------------------
The PRNG key for the token emitted at stream position ``p`` (0-indexed over
prompt + generated tokens) of a request with seed ``s`` is::

    fold_in(key(s), p)

It depends on nothing else — not the decode slot the request landed in, not
which neighbours share the batch, not whether the token came from a decode
step or the final chunk of a (re-)prefill. That last property is what makes
recompute-preemption *forced replay*: a preempted sequence re-prefills
prompt + generated-so-far as forced context (no token is ever re-decided),
and the next token it samples uses the same ``(seed, position)`` key the
uninterrupted run would have used, so resumed sequences are token-identical
under any sampling setting.

The same property makes the multi-step compiled decode loop
(engine ``decode_steps > 1``) token-invisible: the loop derives ``p`` from
the sequence lengths it carries *in-loop* (``lens + 1``, advanced each
iteration on device), so iteration i of a dispatch draws with exactly the
key the i-th single-step dispatch would have — streams are bit-identical
at any horizon, including across a preemption landing between dispatches.

Filtering order follows the common serving convention: temperature scaling,
then top-k, then top-p (nucleus) on the rescaled distribution, then one
draw. ``temperature == 0`` short-circuits to raw ``argmax`` on the unscaled
logits — bit-identical to the historical greedy path.

The top-k/top-p masking itself lives in ``repro.kernels.fused_sampling``:
``fused=True`` (the default) streams it sort-free (Pallas on TPU, a bit-key
bisection in jnp elsewhere), ``fused=False`` runs the single sort-based
reference. The two are bit-identical by construction — they share one
decision predicate — so the flag changes speed, never tokens.

The draw itself is the canonical inverse-CDF walk of
``repro.kernels.fused_lm_head.ref``: one ``jax.random.uniform`` from the
``fold_in(key(seed), position)`` key, then the first vocab index whose
(canonically tiled) prefix softmax mass exceeds ``uniform * Z``. Exact
categorical sampling, and — unlike the Gumbel-noise formulation — needing
no per-vocab-entry randomness, so the fused decode epilogue can reproduce
the identical token while streaming the unembed GEMM over vocab blocks.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp

from repro.kernels.fused_lm_head import ref as head_ref
from repro.kernels.fused_sampling import ops as fused_ops
from repro.kernels.fused_sampling import ref as fused_ref


def fused_sampling_enabled() -> bool:
    """Env default for the engines' ``fused_sampling`` flag: set
    ``REPRO_FUSED_SAMPLING=0`` to fall back to the sort-based reference
    filter everywhere. A debugging escape hatch — the two implementations
    draw bit-identical tokens, so the toggle only changes step latency."""
    return os.environ.get("REPRO_FUSED_SAMPLING", "1") not in ("", "0")


def fused_decode_enabled() -> bool:
    """Env default for the continuous engine's ``fused_decode`` flag: set
    ``REPRO_FUSED_DECODE=0`` to serve the unfused decode path (separate
    residual adds / norms and a materialized-logits sampler). Like the
    sampler flag, the fused and unfused paths emit bit-identical token
    streams by construction, so the toggle only changes memory traffic and
    step latency."""
    return os.environ.get("REPRO_FUSED_DECODE", "1") not in ("", "0")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How one request's tokens are chosen.

    temperature  0 = greedy argmax (the default; exact static/continuous
                 parity). > 0 divides the logits before the softmax draw.
    top_k        keep only the k highest logits (0 = disabled).
    top_p        keep the smallest set of tokens whose probability mass
                 reaches top_p (nucleus sampling; 1.0 = disabled).
    seed         per-request PRNG seed; the draw for stream position p uses
                 fold_in(key(seed), p), nothing else.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0: {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables): {self.top_k}")
        if not 0 < self.top_p <= 1:
            raise ValueError(f"top_p must be in (0, 1]: {self.top_p}")
        if not 0 <= self.seed < 2 ** 32:
            raise ValueError(f"seed must fit in uint32: {self.seed}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0

    @property
    def filtered(self) -> bool:
        """True when top-k or top-p actually constrains the distribution —
        the engines skip the sampler's filtering epilogue entirely
        otherwise."""
        return self.top_k > 0 or self.top_p < 1.0


def sample_tokens(logits: jax.Array, seeds: jax.Array, positions: jax.Array,
                  temperatures: jax.Array, top_k: jax.Array,
                  top_p: jax.Array, *, filtered: bool = True,
                  fused: bool = True) -> jax.Array:
    """Draw one token per row of ``logits`` [B, V] -> int32 [B].

    All parameter arrays are per-row [B]: ``seeds`` uint32, ``positions``
    int32 (the stream position of the token being emitted), ``temperatures``
    / ``top_p`` float32, ``top_k`` int32 (0 = disabled). Rows with
    ``temperature == 0`` return ``argmax(logits)`` on the raw logits —
    bit-identical to the greedy path — and their PRNG work is discarded.

    ``filtered`` is a static (Python) flag: pass False when every row has
    top_k and top_p disabled to skip the filtering epilogue entirely — for
    finite logits the disabled filters are exact no-ops, so both variants
    draw the identical token for the same (seed, position, logits).

    ``fused`` (static) picks the filter implementation: the sort-free
    streaming kernel package (default) or the sort-based reference oracle.
    Bit-identical outputs either way; the flag exists for fallback and for
    divergence regression tests. Traceable/jittable in every combination;
    nothing bigger than the [B] token vector ever crosses to the host.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    temps = temperatures.astype(jnp.float32)
    safe_t = jnp.where(temps > 0, temps, 1.0)
    lg = logits.astype(jnp.float32) / safe_t[:, None]

    if filtered:
        fn = fused_ops.filter_logits if fused else fused_ref.filter_logits_ref
        lg = fn(lg, top_k.astype(jnp.int32), top_p.astype(jnp.float32))

    rs = head_ref.row_uniforms(seeds, positions)
    sampled = head_ref.draw_tokens(lg, rs)
    return jnp.where(temps > 0, sampled, greedy)
