"""ContinuousEngine: sampling-capable serving with continuous batching,
prefix caching, and chunked prefill.

The engine drives the stack through a generic per-layer **decode-state
protocol** (``models.transformer.init_serving_state``): each layer kind
declares its own decode state and its prefill/decode apply. Attention
mixers declare paged KV pools (``[P, page, Hkv, Dh]``, indexed by the
shared page table); mamba mixers declare a pooled, constant-size per-*slot*
state (conv tail + ``[slot, H, N, P]`` SSD state) — recurrent state folds
all history into fixed size, so it rides the decode slot, not pages. That
one protocol serves dense, MoE, VLM, pure-SSM (mamba2), and hybrid (jamba)
families with the same scheduler: slot recycling resets a mamba row at the
next sequence's first chunk, and preemption stays forced replay — the SSM
state is recomputed by re-prefilling the victim's context, so resume is
token-identical. Prefix caching shares *pages*, which recurrent state is
not decomposable into, so SSM-bearing archs gate it off with an explicit
reason on the engine and in every request's result (never a silent no-op).

Shapes the compiler sees are fixed — decode always runs the full
``num_slots`` batch against the same page pools and a [num_slots, max_pages]
page table — so requests join and leave mid-flight without recompiling.
Prompt ingestion is *chunked prefill*: one page-multiple chunk of one
sequence per engine iteration, written straight into the sequence's pages by
the paged-prefill path (``models.transformer.paged_prefill_stack``), so

- a long prompt no longer stalls every running decode for a full-prompt
  forward pass (decode steps interleave between its chunks), and
- the prefill compile cache holds exactly ONE shape (the chunk), not one
  entry per page-aligned bucket length.

Prefix caching closes the loop: the scheduler's radix index matches each
prompt against already-resident pages (shared via refcounts; a partially
matching tail page is copied on divergence — the engine's CoW device copy),
and only the unmatched suffix is chunk-prefilled. Under shared system
prompts this removes most prefill FLOPs *and* most prefill HBM writes.

Tensor parallelism (``tp > 1``) runs the same engine over a 1-D ``("model",)``
device mesh: the page pools are *head-sharded* (each device owns
``num_kv_heads / tp`` heads of every physical page, so page ids — and
therefore the host-side ``PageAllocator``/``PrefixIndex``/scheduler — stay
global and unchanged), the attention/MLP projections are Megatron shards,
and the decode/prefill/copy steps run under ``shard_map`` with one
all-reduce per psum site (attention output; MLP output or MoE combine).
When ``tp > num_kv_heads``, KV projections and pools are *replicated*
head-major (``kv_rep = tp / Hkv`` shards per KV head) so each shard still
owns one whole head. MoE layers run expert-parallel: routed experts shard
E-major (each device owns ``E / tp`` complete experts, routing replicated)
and the combine meets in the layer's single psum. Mamba mixers stay
replicated — collective-free. Embedding, norms, and the LM head stay
replicated, so every shard computes identical logits and identical sampler
draws — the emitted token vector needs no collective, and greedy/seeded
streams are token-identical across tp values and to the single-device
engine (including preemption replay).

Token selection is the shared on-device sampler (``serving.sampling``):
each request carries ``SamplingParams`` (temperature / top-k / top-p /
seed), and the key for the token at stream position p is
``fold_in(key(seed), p)`` — independent of the slot the request landed in,
of its co-batched neighbours, and of whether the token came from a decode
step or the final chunk of a (re-)prefill. At ``temperature == 0`` the
sampler short-circuits to raw argmax, bit-identical to the historical
greedy engine, and preemption is *forced replay* either way: a victim's
prompt + generated tokens are re-prefilled as forced context, so the resumed
stream is token-identical under any sampling setting (the invariant
``tests/test_sampling.py`` pins, including mid-prefill and CoW-tail
preemptions).

Multi-step compiled decode (``decode_steps = N > 1``) moves N decode
iterations into one on-device ``lax.while_loop`` per host dispatch
(``models.transformer.paged_decode_loop``): the loop carries the sampled
token, per-slot sequence lengths (positions — and therefore PRNG keys —
advance *in-carry*, which is what keeps streams bit-identical to N=1), the
emitted-token buffer, and an exit-reason vector, and exits *globally* the
first iteration any active slot hits EOS, its token budget, or its
pre-allocated page capacity — so every returned token is valid and the
host appends exactly ``k`` tokens per active slot. The host resyncs once
per dispatch: it pre-computes the per-slot predicates (budget left, page
capacity via ``Scheduler.extend_capacity`` — free pages only, never a
preemption), then reconciles the returned ``(buffer, k, reasons)`` through
the ordinary finish/admit/preempt path. Invariants the tests pin:

- jit-cache key: ``("decode", sampled, filtered, fused, fd)`` at N=1 (the
  single-step path is literally unchanged) and
  ``("decode", sampled, filtered, fused, fd, N)`` at N>1 — prefill keys
  never carry the horizon. ``fd`` is the engine's ``fused_decode`` flag
  (below). ``analysis/recompile.py`` audits both shapes closed.
- ``steps`` counts loop iterations, ``decode_dispatches`` host dispatches,
  ``decode_exits`` why each dispatch returned; at N=1 the two counters are
  equal and no exit accounting runs.
- a preemption can only land *between* dispatches; forced replay re-derives
  every key from stream position, so the horizon is token-invisible.

Fused decode (``fused_decode = True``, the default where supported) removes
the residual-stream HBM round-trips at every fused norm site and the [S, V]
logits buffer entirely: inside each layer period the residual rides as an
(x, pending-delta) pair folded by the fused residual+norm kernels
(``kernels.fused_layernorm.decode_residual_norm``) and completed by a plain
add at the period boundary (so the scan carry — and XLA's context-sensitive
lowering of the norm reductions — matches the unfused body exactly), and
the LM head + token selection collapse into a vocab-tiled streaming
epilogue (``kernels.fused_lm_head``) that carries max/argmax, the top-k/
top-p bisection counts, softmax masses, and the inverse-CDF draw in the
GEMM accumulator. Token streams are bit-identical to the unfused path —
greedy and seeded-sampled, across preemption replay, decode_steps horizons,
and tp — because every float reduction is the same canonically-tiled sum on
both paths and every residual add sits at the same graph position.
Unsupported layouts (post-norm stacks, MLM heads, TP shards off the
reduction tile) fall back with ``fused_decode_off_reason`` set.
"""
from __future__ import annotations

import functools
import time
from collections import deque
from typing import Any, Deque, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..analysis.sanitize import (check_engine, check_finite_probe,
                                 sanitize_enabled)
from ..kernels.fused_lm_head import ops as head_ops
from ..kernels.fused_lm_head import ref as head_ref
from ..models import transformer as tf
from ..models.layers import apply_norm, pad_vocab, unembed
from ..models.model import Model
from ..models.moe import capacity_per_row
from ..parallel import sharding as shardlib
from .kv_cache import pages_needed
from .sampling import (fused_decode_enabled, fused_sampling_enabled,
                       sample_tokens)
from .scheduler import Request, Scheduler, SequenceState

SERVABLE_FAMILIES = ("dense", "moe", "vlm", "ssm", "hybrid")

TP_AXIS = "model"


def _split_fused_qkv(params, arch):
    """Replace every attention block's fused ``wqkv``/``bqkv`` with the
    equivalent ``wq/wk/wv`` (``bq/bk/bv``) column slices.

    Head-sharding needs head-major contiguous weight columns per projection;
    a slice of the *fused* feature dim would mix q and kv columns. The split
    is exact — each output column's GEMM is untouched — so tp > 1 engines
    built from fused-init params emit bit-identical projections. Handles
    both period-dict and scanned (leading period axis) layouts, since the
    split runs on the trailing axis.
    """
    cuts = [arch.q_dim, arch.q_dim + arch.kv_dim]

    def walk(tree):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for key, val in tree.items():
            if key == "attn" and isinstance(val, dict) and "wqkv" in val:
                val = dict(val)
                wq, wk, wv = jnp.split(val.pop("wqkv"), cuts, axis=-1)
                val.update(wq=wq, wk=wk, wv=wv)
                if "bqkv" in val:
                    bq, bk, bv = jnp.split(val.pop("bqkv"), cuts, axis=-1)
                    val.update(bq=bq, bk=bk, bv=bv)
            out[key] = walk(val)
        return out
    return walk(params)


def _replicate_kv_heads(params, arch, rep: int):
    """Repeat every K/V projection's head blocks ``rep`` times (head-major:
    new head j holds old head j // rep), so the column-parallel slice of a
    ``tp > Hkv`` mesh lands each shard on one complete KV head.

    The GQA math is untouched: shard i's Hq/tp query heads all group onto
    old KV head ``i // rep``, which is exactly the replicated block the
    shard receives — attention per shard is a smaller-head instance of the
    single-device layer, at rep x the global KV memory (the price of
    replication, reported by ``tp_stats``)."""
    hd = arch.resolved_head_dim

    def rep_heads(w):
        # [..., Hkv * hd] -> [..., Hkv, hd] -> repeat -> [..., Hkv * rep * hd]
        shape = w.shape[:-1] + (w.shape[-1] // hd, hd)
        r = jnp.repeat(w.reshape(shape), rep, axis=-2)
        return r.reshape(w.shape[:-1] + (w.shape[-1] * rep,))

    def walk(tree):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for key, val in tree.items():
            if key == "attn" and isinstance(val, dict) and "wk" in val:
                val = dict(val)
                for name in ("wk", "wv", "bk", "bv"):
                    if name in val:
                        val[name] = rep_heads(val[name])
            out[key] = walk(val)
        return out
    return walk(params)


class ContinuousEngine:
    def __init__(self, model: Model, params, *, num_slots: int = 8,
                 num_pages: int = 256, page_size: int = 16,
                 max_seq_len: int = 512, prefix_cache: bool = True,
                 prefill_chunk: Optional[int] = None, tp: int = 1,
                 mesh=None, sanitize: Optional[bool] = None,
                 fused_sampling: Optional[bool] = None,
                 decode_steps: int = 1,
                 fused_decode: Optional[bool] = None):
        arch = model.arch
        assert arch.family in SERVABLE_FAMILIES, \
            (f"continuous engine serves families {SERVABLE_FAMILIES}; "
             f"{arch.name} is {arch.family!r}")
        assert not arch.bidirectional, "encoder-only archs have no decode step"
        kinds = tf.layer_kinds(arch)
        self.has_attn = any(m == "attn" for m, _ in kinds)
        self.has_ssm = any(m == "mamba" for m, _ in kinds)
        if self.has_attn:
            assert arch.num_heads > 0
            assert arch.pos_emb in ("rope", "mrope", "none"), \
                "paged decode re-derives positions from seq_lens " \
                "(rope/mrope/none only)"
            assert arch.window == 0, \
                "paged decode-attention has no sliding-window masking yet"
        self.model = model
        self.arch = arch
        self.page_size = page_size
        self.num_slots = num_slots
        self.max_pages_per_seq = pages_needed(max_seq_len, page_size)
        if prefill_chunk is None:
            prefill_chunk = 4 * page_size
        assert prefill_chunk % page_size == 0 and prefill_chunk > 0, \
            "prefill chunk must be a positive page multiple"
        self.prefill_chunk = prefill_chunk
        # runtime sanitizer (repro.analysis.sanitize): host invariant sweep
        # after every request completion + NaN/Inf probes compiled into the
        # steps. Static per engine — probe variants live in the jit cache
        # keyed by construction, so toggling means a new engine, not a
        # retrace of this one.
        self.sanitize = sanitize_enabled() if sanitize is None \
            else bool(sanitize)
        # sort-free streaming top-k/top-p filter (repro.kernels.
        # fused_sampling) vs the sort-based reference — bit-identical token
        # streams either way; the flag is a fallback + parity-test hook.
        # Static per engine like `sanitize`: it names the filter
        # implementation inside the compiled filtered variants.
        self.fused_sampling = fused_sampling_enabled() if fused_sampling \
            is None else bool(fused_sampling)
        # multi-step compiled decode: decode_steps > 1 dispatches up to N
        # iterations as one on-device lax.while_loop (tf.paged_decode_loop)
        # and resyncs with the host only on an exit event (EOS, token/page
        # budget) or the horizon. Static per engine — the horizon is part of
        # the decode variant's jit-cache key, so changing N means a new
        # variant, never a retrace. Token streams are bit-identical across N
        # (positions advance in-carry exactly as the host would have).
        assert decode_steps >= 1, decode_steps
        self.decode_steps = int(decode_steps)
        # fused decode residual stream + streaming LM head: the decode/
        # final-prefill steps fold each residual-add + pre-norm pair into
        # one fused kernel pass inside the layer period and run the unembed
        # GEMM as a vocab-tiled streaming epilogue that samples
        # in-accumulator — no [S, V] logits buffer ever reaches HBM.
        # Bit-identical token
        # streams by construction (tests pin greedy + seeded-sampled parity
        # incl. preemption replay), so the flag only changes memory traffic.
        # Static per engine and part of every step's jit-cache key. Falls
        # back (with a recorded reason) where the fusion's preconditions
        # fail: post-norm stacks, MLM heads, and TP shard widths that don't
        # land on the canonical reduction tile.
        want_fd = fused_decode_enabled() if fused_decode is None \
            else bool(fused_decode)
        self.fused_decode_off_reason: Optional[str] = None
        if want_fd:
            if arch.post_norm:
                self.fused_decode_off_reason = \
                    "fused decode requires a pre-norm stack"
            elif arch.mlm_transform:
                self.fused_decode_off_reason = \
                    "fused decode does not support MLM-transform heads"
            elif not head_ops.tp_fusable(pad_vocab(arch.vocab_size), tp):
                self.fused_decode_off_reason = (
                    f"vocab shard {pad_vocab(arch.vocab_size)}/{tp} does not "
                    f"land on the {head_ops.RED_TILE}-wide reduction tile")
        self.fused_decode = want_fd and self.fused_decode_off_reason is None
        # prefix caching shares *pages*; a mamba mixer's recurrent state is
        # not page-decomposable (a cached KV page is useless without the SSM
        # state at its boundary), so SSM-bearing archs gate it off — loudly:
        # the reason lands on the engine AND in every request's result
        self.prefix_cache_off_reason: Optional[str] = None
        if self.has_ssm and prefix_cache:
            self.prefix_cache_off_reason = (
                "prefix cache unsupported for SSM-bearing archs "
                f"({arch.name}): recurrent state is not page-decomposable")
            prefix_cache = False
        self.scheduler = Scheduler(num_slots=num_slots, num_pages=num_pages,
                                   page_size=page_size,
                                   max_pages_per_seq=self.max_pages_per_seq,
                                   prefix_cache=prefix_cache)
        self.pools = tf.init_serving_state(arch, num_pages, page_size,
                                           num_slots, jnp.dtype(arch.dtype))

        # ---- tensor parallelism over a 1-D ("model",) mesh -------------------
        assert tp >= 1, tp
        self.tp = tp
        self.kv_rep = 1
        # psums per period: one per attention output, one per MLP/MoE tail
        # (mamba mixers are replicated — collective-free)
        self._psums_per_step = sum(
            (1 if mixer == "attn" else 0) + (0 if arch.family == "ssm" else 1)
            for mixer, _ in kinds) * (arch.num_layers // len(kinds))
        if tp > 1:
            if arch.moe is not None:
                assert arch.moe.num_experts % tp == 0, \
                    (f"tp={tp} must divide the expert count "
                     f"({arch.moe.num_experts}) — expert-parallel layout")
                if arch.moe.num_shared_experts:
                    shared_ff = (arch.moe.expert_ff or arch.d_ff) \
                        * arch.moe.num_shared_experts
                    assert shared_ff % tp == 0, (shared_ff, tp)
            if self.has_attn:
                assert arch.num_heads % tp == 0, \
                    (f"tp={tp} must divide query heads ({arch.num_heads}) — "
                     "head-sharded layout")
                hkv = arch.num_kv_heads
                assert hkv % tp == 0 or tp % hkv == 0, \
                    (f"tp={tp} must divide the KV heads ({hkv}) or be a "
                     "multiple of them (KV-head replication)")
                if hkv % tp:
                    self.kv_rep = tp // hkv
            if arch.d_ff:
                assert arch.d_ff % tp == 0, (arch.d_ff, tp)
            if mesh is None:
                from ..launch.mesh import make_tp_mesh
                mesh = make_tp_mesh(tp)
            assert mesh.shape[TP_AXIS] == tp, (dict(mesh.shape), tp)
            self.mesh = mesh
            self.tp_axis: Optional[str] = TP_AXIS
            # fused qkv cannot be head-sharded; split (exact) then shard
            params = _split_fused_qkv(params, arch)
            if self.kv_rep > 1:
                # tp > Hkv: replicate each KV head across tp/Hkv shards so
                # the head-major column slice stays one whole head per shard
                params = _replicate_kv_heads(params, arch, self.kv_rep)
                self.pools = jax.tree_util.tree_map_with_path(
                    lambda kp, l: jnp.repeat(l, self.kv_rep, axis=-2)
                    if str(kp[-1].key) in shardlib.PAGED_STATE_LEAVES else l,
                    self.pools)
            self._param_specs = shardlib.serving_param_pspecs(params)
            self._pool_specs = shardlib.paged_pool_pspecs(self.pools)
            params = jax.device_put(params, jax.tree.map(
                lambda s: NamedSharding(mesh, s), self._param_specs,
                is_leaf=lambda s: isinstance(s, P)))
            self.pools = jax.device_put(self.pools, jax.tree.map(
                lambda s: NamedSharding(mesh, s), self._pool_specs,
                is_leaf=lambda s: isinstance(s, P)))
        else:
            self.mesh = None
            self.tp_axis = None
            self._param_specs = self._pool_specs = None
        self.params = params

        self.steps = 0                  # decode steps executed (for stats)
        self.decode_dispatches = 0      # host round-trips those steps cost
        # why multi-step dispatches came back to the host (per active slot
        # bit for eos/budgets; one count per full-horizon dispatch)
        self.decode_exits = {"eos": 0, "token_budget": 0, "page_budget": 0,
                             "horizon": 0}
        self.prefills = 0               # prefill completions (== admissions)
        self.prefill_tokens = 0         # prompt tokens actually computed
        self.cached_prefill_tokens = 0  # prompt tokens served from the cache
        self.cow_copies = 0             # divergent tail pages duplicated
        self.collective_bytes = 0       # analytic TP wire bytes per device
        self._prefilling: Deque[SequenceState] = deque()
        # donate the page pools through decode AND prefill: without it each
        # call copies every layer's [P, page, Hkv, D] pool to update a few rows
        self._donate_pools = jax.default_backend() in ("tpu", "gpu")
        # one compiled entry per static variant (the flags select which
        # sampler work exists at all); built lazily so e.g. all-greedy
        # traffic never compiles a sampled step
        self._jit_cache: Dict[Tuple, Any] = {}
        # the compiled all-greedy decode variant never reads the sampling
        # arrays; ship these cached placeholders instead of rebuilding and
        # re-transferring [S] arrays every step of the default path
        self._null_sampling = (
            jnp.zeros((num_slots,), jnp.uint32),    # seeds
            jnp.zeros((num_slots,), jnp.float32),   # temperatures
            jnp.zeros((num_slots,), jnp.int32),     # top_k
            jnp.ones((num_slots,), jnp.float32),    # top_p
        )
        # sampled traffic reuses its per-slot sampling arrays too: they only
        # change when a slot is (re)assigned, so the decode loop rebuilds
        # them on composition change instead of paying four host->device
        # transfers per step (positions are derived on device from seq_lens
        # — see _decode_impl). This host tax, not the filter math, was most
        # of the sampled-vs-greedy throughput gap.
        self._sampling_key: Optional[Tuple] = None
        self._sampling_args = self._null_sampling

    # ------------------------------------------------------------ jit builders --
    def _build(self, impl, in_specs, out_specs, donate, key=()):
        """jit (and, at tp > 1, shard_map) one static variant of a step.

        ``key`` is the jit-cache key this compiled step lives under — unused
        here, but the recompilation auditor (``repro.analysis.recompile``)
        overrides this method and needs it to attribute trace signatures."""
        if self.mesh is not None:
            impl = shardlib.shard_map_tp(impl, self.mesh, in_specs, out_specs)
        return jax.jit(impl,
                       donate_argnums=donate if self._donate_pools else ())

    def _decode_fn(self, sampled: bool, filtered: bool):
        # `fused` names the filter implementation, so it only exists in
        # variants that filter at all — greedy/temperature-only variants
        # stay shared between fused and reference engines. `fd` (fused
        # decode) reshapes the whole step — residual-stream pair carry plus
        # the streaming LM-head epilogue — so it keys every variant.
        fused = self.fused_sampling and filtered
        key = ("decode", sampled, filtered, fused, self.fused_decode)
        if key not in self._jit_cache:
            impl = functools.partial(self._decode_impl, sampled=sampled,
                                     filtered=filtered, fused=fused,
                                     fd=self.fused_decode)
            in_specs = (self._param_specs, self._pool_specs, P(None, None),
                        P(None), P(None), P(None), P(None), P(None), P(None))
            out_specs = (P(None), self._pool_specs)
            if self.sanitize:
                out_specs += (P(),)     # the replicated isfinite probe
            self._jit_cache[key] = self._build(
                impl, in_specs, out_specs, donate=(1,), key=key)
        return self._jit_cache[key]

    def _decode_multi_fn(self, sampled: bool, filtered: bool):
        """The multi-step decode variant: same static flags as
        ``_decode_fn`` plus the horizon N, which keys the jit cache — an
        engine at ``decode_steps=N`` compiles (lazily, per sampling
        variant) loops of exactly that horizon and nothing else."""
        fused = self.fused_sampling and filtered
        key = ("decode", sampled, filtered, fused, self.fused_decode,
               self.decode_steps)
        if key not in self._jit_cache:
            impl = functools.partial(self._decode_multi_impl, sampled=sampled,
                                     filtered=filtered, fused=fused,
                                     fd=self.fused_decode,
                                     horizon=self.decode_steps)
            in_specs = (self._param_specs, self._pool_specs, P(None, None)) \
                + (P(None),) * 10
            out_specs = (P(None, None), P(), P(None), self._pool_specs)
            if self.sanitize:
                out_specs += (P(),)     # the replicated isfinite probe
            self._jit_cache[key] = self._build(
                impl, in_specs, out_specs, donate=(1,), key=key)
        return self._jit_cache[key]

    def _prefill_fn(self, final: bool, sampled: bool, filtered: bool):
        fused = self.fused_sampling and filtered
        key = ("prefill", final, sampled, filtered, fused, self.fused_decode)
        if key not in self._jit_cache:
            impl = functools.partial(self._prefill_impl, final=final,
                                     sampled=sampled, filtered=filtered,
                                     fused=fused, fd=self.fused_decode)
            in_specs = (self._param_specs, self._pool_specs, P(None, None),
                        P(None), P(), P(), P(), P(), P(), P(), P(), P())
            out_specs = (P(), self._pool_specs)
            if self.sanitize:
                out_specs += (P(),)
            self._jit_cache[key] = self._build(
                impl, in_specs, out_specs, donate=(1,), key=key)
        return self._jit_cache[key]

    def _copy_page_fn(self):
        key = ("copy",)
        if key not in self._jit_cache:
            # pools are argument 0 here, not 1
            self._jit_cache[key] = self._build(
                self._copy_page_impl, (self._pool_specs, P(), P()),
                self._pool_specs, donate=(0,), key=key)
        return self._jit_cache[key]

    def _tp_collective_bytes(self, positions: int) -> int:
        """Analytic per-device wire bytes for one step's collectives: one
        fp32 [positions, d_model] ring all-reduce per psum (attention
        output, MLP output / MoE combine — mamba mixers are replicated and
        contribute none), each moving 2 * (tp-1)/tp of its payload per
        device."""
        if self.tp <= 1:
            return 0
        payload = positions * self.arch.d_model * 4
        return self._psums_per_step * payload * 2 * (self.tp - 1) // self.tp

    # ------------------------------------------------------------- jitted fns ---
    def _fused_head(self, params, x, positions, seeds, temps, top_ks,
                    top_ps, *, sampled, filtered, fused):
        """Fused final-norm + streaming LM head: final hidden ``x``
        [S, 1, D] -> ``(tokens [S], ok [S])`` with no [S, V] logits buffer.

        On TPU the unembed GEMM streams over vocab tiles with the sampling
        statistics (max/argmax, filter-threshold bisections, softmax
        masses, the inverse-CDF draw) carried in the accumulator —
        bit-identical to materializing the logits and running
        ``sample_tokens`` (the ``fused_decode`` contract; the tiled
        reductions are the canonical ones both paths share). ``ok`` is the
        per-row finite probe from the same streaming sweep. Under TP each
        shard streams its own vocab slice and the combines move
        O(S * V / RED_TILE) statistics, never logits.

        Off-TPU the fallback is the *op-identical* unfused tail (full
        unembed + ``sample_tokens``), not the jnp streaming emulation: XLA
        CPU lowers float reductions context-sensitively, so two graphs
        that differ anywhere downstream of a norm or GEMM can round the
        SAME math to ulp-different values — the only structure that
        guarantees the fused_decode bit-parity contract on CPU is one
        whose HLO is identical. The streaming emulation stays covered by
        the standalone and interpret-mode parity tests (where jit-vs-jit
        equality holds because both sides are whole graphs)."""
        arch = self.arch
        x = shardlib.constrain(x, "batch", None, None)
        hidden = apply_norm(arch.norm, params["final_norm"], x)
        if not head_ops.supported():
            tied = params["embed"]["embedding"] if arch.tie_embeddings \
                else None
            logits = unembed(params.get("out", {}), hidden, tied,
                             arch.logit_softcap)[:, 0]
            if not sampled:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                tok = sample_tokens(logits, seeds, positions, temps,
                                    top_ks, top_ps, filtered=filtered,
                                    fused=fused)
            return tok, jnp.isfinite(logits).all(axis=-1)
        if arch.tie_embeddings:
            w = params["embed"]["embedding"].astype(hidden.dtype).T
        else:
            w = params["out"]["head"].astype(hidden.dtype)
        w = shardlib.constrain(w, None, "vocab")
        hidden = hidden.reshape(hidden.shape[0], hidden.shape[-1])
        rs = head_ref.row_uniforms(seeds, positions)
        softcap = arch.logit_softcap if arch.logit_softcap > 0 else None
        return head_ops.head_tokens(
            hidden, w, rs, temps, top_ks, top_ps, sampled=sampled,
            filtered=filtered, softcap=softcap, axis_name=self.tp_axis,
            tp=self.tp)

    def _decode_impl(self, params, pools, page_table, seq_lens, tokens,
                     seeds, temps, top_ks, top_ps, *, sampled, filtered,
                     fused, fd):
        """tokens [S] -> (next token [S], new pools). S == num_slots.

        Selection stays on device — greedy slots take a raw argmax, sampled
        slots a per-slot (seed, position)-keyed categorical draw — so only
        the [S] token vector ever crosses to the host, never [S, vocab]
        logits. ``sampled``/``filtered``/``fused`` are static: an all-greedy
        step compiles to a pure argmax (today's default traffic pays zero
        sampler work — no filtering, no key fold-ins), temperature-only
        batches skip the filtering epilogue, filtered batches run either the
        streaming fused filter or the sort-based reference, and each extra
        variant compiles only once the matching traffic shows up.

        ``fd`` (fused decode) swaps both halves of the step: the stack runs
        the residual+norm-fused layer bodies, and the final-norm + LM head
        + selection collapse into the streaming vocab-tiled epilogue of
        ``_fused_head`` — same tokens, same probe semantics, no [S, V]
        logits round-trip."""
        x = self.model._embed(params, tokens[:, None])
        if fd:
            x, pools = tf.paged_decode_stack(
                self.arch, params["blocks"], pools, x, page_table, seq_lens,
                tp_axis=self.tp_axis, fused=True)
            tok, ok = self._fused_head(params, x, seq_lens + 1, seeds,
                                       temps, top_ks, top_ps, sampled=sampled,
                                       filtered=filtered, fused=fused)
            if self.sanitize:
                return tok, pools, jnp.all(ok | (seq_lens == 0))
            return tok, pools
        x, pools = tf.paged_decode_stack(self.arch, params["blocks"], pools,
                                         x, page_table, seq_lens,
                                         tp_axis=self.tp_axis)
        logits = self.model._logits(params, x)[:, 0]
        if not sampled:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            # stream position of the token this step emits, derived ON
            # DEVICE: every earlier token of the sequence is cached except
            # the step's input token, so position = seq_lens + 1. Slot- and
            # batch-independent (the determinism contract), and it spares
            # sampled steps any per-step position transfer. Mid-prefill
            # slots are masked to seq_lens 0 and temperature 0; their draws
            # are discarded on the host.
            positions = seq_lens + 1
            tok = sample_tokens(logits, seeds, positions, temps, top_ks,
                                top_ps, filtered=filtered, fused=fused)
        if self.sanitize:
            # inactive slots read the null page and may legitimately produce
            # junk — probe only rows with at least one real token resident
            live = jnp.isfinite(logits) | (seq_lens[:, None] == 0)
            return tok, pools, live.all()
        return tok, pools

    def _decode_multi_impl(self, params, pools, page_table, seq_lens, tokens,
                           active, budget, page_limit, eos_ids, seeds, temps,
                           top_ks, top_ps, *, sampled, filtered, fused, fd,
                           horizon):
        """tokens [S] -> (emitted tokens [horizon, S], steps executed,
        exit-reason bits [S], new pools). One ``lax.while_loop`` around the
        exact single-step body (``tf.paged_decode_loop``): up to ``horizon``
        tokens per slot leave the device per host round-trip instead of one.

        ``active``/``budget``/``page_limit``/``eos_ids`` are the host's
        per-slot loop predicates (decode-eligible mask, remaining token
        allowance, allocated-page capacity in tokens, eos id or -1); the
        sampling arrays are the same per-slot params the single-step variant
        takes, with positions advanced in-carry so every draw's (seed,
        position) key — and therefore every token — matches ``decode_steps=1``
        bit-for-bit."""
        def embed(tok):
            return self.model._embed(params, tok)

        def unembed(x):
            return self.model._logits(params, x)[:, 0]

        def select(logits, positions):
            if not sampled:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return sample_tokens(logits, seeds, positions, temps, top_ks,
                                 top_ps, filtered=filtered, fused=fused)

        def fused_head(x, positions):
            # the loop body's LM head on the fused-decode path: streaming
            # epilogue straight off the final hidden, finite probe included
            return self._fused_head(params, x, positions, seeds,
                                    temps, top_ks, top_ps, sampled=sampled,
                                    filtered=filtered, fused=fused)

        return tf.paged_decode_loop(
            self.arch, params["blocks"], pools, tokens, page_table, seq_lens,
            active, budget, page_limit, eos_ids, horizon=horizon, embed=embed,
            unembed=unembed, select=select, probe=self.sanitize,
            tp_axis=self.tp_axis, fused_head=fused_head if fd else None)

    def _prefill_impl(self, params, pools, tokens, page_row, slot, start,
                      total, moe_cap, seed, temp, top_k, top_p, *, final,
                      sampled, filtered, fused, fd):
        """One prompt chunk of one sequence. tokens [1, C] (padded past
        ``total - start`` valid tokens) -> (token after the chunk's last
        valid token [scalar], new pools). One compiled shape (variants on
        the static flags only: non-final chunks exist to fill pages and skip
        the LM head entirely; a final chunk pays the head plus either a raw
        argmax or the sampler, like ``_decode_impl``). ``slot`` addresses
        the sequence's per-slot SSM state rows, ``moe_cap`` is the full
        context's MoE capacity (host-computed with the static engine's exact
        math; attention-only / MoE-free stacks ignore them). The emitted
        token's stream position is ``total``, so its sampling key matches
        the decode step that would have produced it in an uninterrupted run
        — the forced-replay invariant.

        ``fd``: the chunk runs the residual+norm-fused layer bodies; a
        final chunk slices the sampling position and runs the same fused
        final-norm + streaming-head epilogue as the decode step."""
        x = self.model._embed(params, tokens)
        if fd:
            x, pools = tf.paged_prefill_stack(
                self.arch, params["blocks"], pools, x, page_row, start,
                total, slot, moe_cap, tp_axis=self.tp_axis, fused=True)
            if not final:
                if self.sanitize:
                    pos = start + jnp.arange(x.shape[1])
                    live = jnp.isfinite(x) | (pos >= total)[None, :, None]
                    return jnp.zeros((), jnp.int32), pools, live.all()
                return jnp.zeros((), jnp.int32), pools
            xl = tf.chunk_final_hidden(x, start, total)
            toks, ok = self._fused_head(
                params, xl, total[None], seed[None], temp[None],
                top_k[None], top_p[None], sampled=sampled, filtered=filtered,
                fused=fused)
            if self.sanitize:
                return toks[0], pools, ok[0]
            return toks[0], pools
        x, pools = tf.paged_prefill_stack(self.arch, params["blocks"], pools,
                                          x, page_row, start, total, slot,
                                          moe_cap, tp_axis=self.tp_axis)
        if not final:
            if self.sanitize:
                # chunk-boundary probe: activations of the chunk's valid
                # positions (pad rows past ``total - start`` may be junk)
                pos = start + jnp.arange(x.shape[1])
                live = jnp.isfinite(x) | (pos >= total)[None, :, None]
                return jnp.zeros((), jnp.int32), pools, live.all()
            return jnp.zeros((), jnp.int32), pools
        xl = tf.chunk_final_hidden(x, start, total)
        logits = self.model._logits(params, xl)[:, 0]
        if not sampled:
            tok = jnp.argmax(logits[0], axis=-1).astype(jnp.int32)
        else:
            tok = sample_tokens(logits, seed[None], total[None], temp[None],
                                top_k[None], top_p[None], filtered=filtered,
                                fused=fused)[0]
        if self.sanitize:
            return tok, pools, jnp.isfinite(logits).all()
        return tok, pools

    def _copy_page_impl(self, pools, src, dst):
        """Copy-on-write: duplicate one physical page across every attention
        layer. Mamba slot-state leaves have no pages — CoW only exists under
        prefix caching, which SSM-bearing archs gate off, but the leaf map
        stays name-aware so the step is well-defined for any stack."""
        def leaf(key_path, pool):
            if str(key_path[-1].key) not in shardlib.PAGED_STATE_LEAVES:
                return pool
            if pool.ndim == 5:          # scanned stack: [nper, P, page, H, D]
                return pool.at[:, dst].set(pool[:, src])
            return pool.at[dst].set(pool[src])
        return jax.tree_util.tree_map_with_path(leaf, pools)

    # --------------------------------------------------------------- prefill ----
    def _start_prefill(self, seq: SequenceState) -> None:
        """Execute the admission's CoW copy (if any) and queue the suffix."""
        if seq.cow is not None:
            src, dst = seq.cow
            self.pools = self._copy_page_fn()(self.pools, jnp.int32(src),
                                              jnp.int32(dst))
            self.scheduler.cow_done(seq)
            self.cow_copies += 1
        self.cached_prefill_tokens += seq.cached_len
        self._prefilling.append(seq)

    def _advance_prefill(self, now) -> None:
        """Run ONE chunk of the oldest pending prefill; on the final chunk,
        emit the sequence's next token (sampled at stream position
        ``prefill_target`` under the request's SamplingParams) and publish
        its pages into the prefix index."""
        sched = self.scheduler
        while self._prefilling:
            seq = self._prefilling[0]
            if sched.running.get(seq.slot) is not seq:
                self._prefilling.popleft()      # preempted while waiting
                continue
            ctx = seq.context
            start = seq.prefilled
            end = min(start + self.prefill_chunk, seq.prefill_target)
            chunk = np.zeros((1, self.prefill_chunk), np.int32)
            chunk[0, :end - start] = ctx[start:end]
            page_row = jnp.asarray(sched.cache.page_table[seq.slot])
            sp = seq.request.sampling
            final = end == seq.prefill_target
            # `sampled`/`filtered` only matter on the final chunk; pin
            # them False otherwise so non-final chunks share one variant
            prefill = self._prefill_fn(final, final and not sp.greedy,
                                       final and not sp.greedy and sp.filtered)
            # full-context MoE capacity, computed host-side with the exact
            # math the static engine's dispatch uses (capacity_per_row)
            moe_cap = capacity_per_row(seq.prefill_target, self.arch.moe) \
                if self.arch.moe is not None else 0
            out = prefill(
                self.params, self.pools, jnp.asarray(chunk), page_row,
                jnp.int32(seq.slot), jnp.int32(start), jnp.int32(end),
                jnp.int32(moe_cap),
                jnp.uint32(sp.seed), jnp.float32(sp.temperature),
                jnp.int32(sp.top_k), jnp.float32(sp.top_p))
            if self.sanitize:
                tok, self.pools, probe = out
                check_finite_probe(
                    probe, f"prefill chunk [{start}:{end}) of request "
                           f"{seq.request.uid} (final={final})")
            else:
                tok, self.pools = out
            seq.prefilled = end
            self.prefill_tokens += end - start
            self.collective_bytes += self._tp_collective_bytes(
                self.prefill_chunk)
            if end == seq.prefill_target:
                self._prefilling.popleft()
                self.prefills += 1
                sched.register_prefix(seq.slot, ctx)
                # jaxlint: allow[hot-host-sync] the scheduler must see the
                # chunk's token before it can admit/close the sequence —
                # one designed sync per prefill chunk, not per model step
                seq.generated.append(int(tok))
                seq.token_times.append(now())
            return

    def _prefill_pending(self, slot: int) -> bool:
        seq = self.scheduler.running.get(slot)
        return seq is not None and seq.prefilled < seq.prefill_target

    # ------------------------------------------------------------------- run ----
    def run(self, requests: Sequence[Request], *,
            time_fn=time.perf_counter) -> Dict[int, dict]:
        """Serve a trace to completion. Requests with ``arrival > 0`` are held
        back until the trace clock reaches them. Returns
        uid -> {"tokens", "token_times", "prompt_len"[, "error"]}."""
        sched = self.scheduler
        pending = deque(sorted(requests, key=lambda r: (r.arrival, r.uid)))
        results: Dict[int, dict] = {}
        t0 = time_fn()
        skip = 0.0                      # simulated idle time (frozen time_fn)

        def now() -> float:
            return time_fn() - t0 + skip

        def finish(seq: SequenceState) -> None:
            # context[:-1] is what's actually in the pages (the last generated
            # token's K/V was never written) — publish it before releasing
            sched.register_prefix(seq.slot, seq.context[:-1])
            sched.finish(seq)
            results[seq.request.uid] = {
                "tokens": list(seq.generated),
                "token_times": list(seq.token_times),
                "prompt_len": len(seq.request.prompt),
                # per-request prefix accounting: how many prompt tokens this
                # request got from cached pages — 0 with a reason when the
                # engine gated the cache off (never a silent no-op)
                "cached_prefill_tokens": seq.cached_len,
            }
            if self.prefix_cache_off_reason is not None:
                results[seq.request.uid]["prefix_cache"] = \
                    f"off: {self.prefix_cache_off_reason}"
            if self.sanitize:
                # full host-invariant sweep at every request boundary: a
                # leak/desync raises naming the request that exposed it
                check_engine(self)

        while pending or sched.has_work:
            while pending and pending[0].arrival <= now():
                sched.submit(pending.popleft())

            # a prefill whose sequence was preempted must not gate admission
            # (or trip the stall check below against an admittable queue)
            while self._prefilling and sched.running.get(
                    self._prefilling[0].slot) is not self._prefilling[0]:
                self._prefilling.popleft()
            # with the prefix cache on, admit only while no prefill is in
            # flight (one admission per iteration): serializing admission
            # behind the running prefill lets a later request prefix-match
            # the pages the current one is about to register, which
            # same-wave admission would miss. With it off there is nothing
            # to match — admit everything that fits, PR-1 style
            while sched.prefix is None or not self._prefilling:
                seq = sched.admit_next()
                if seq is None:
                    break
                self._start_prefill(seq)
            for req in sched.take_rejected():
                results[req.uid] = {
                    "tokens": [], "token_times": [],
                    "prompt_len": len(req.prompt),
                    "error": "context exceeds max_seq_len "
                             f"({self.max_pages_per_seq} pages/seq)",
                }

            # one prompt chunk per iteration: decode steps interleave between
            # a long prompt's chunks instead of stalling behind it. The token
            # emitted on the final chunk is always a *new* token: the first
            # generation for a fresh request, the continuation for a resumed
            # preemption (whose prompt + generated context is re-prefilled
            # forced — replay never re-decides an already-emitted token).
            self._advance_prefill(now)
            for slot in list(sched.running):
                seq = sched.running[slot]
                if seq.done and not self._prefill_pending(slot):
                    finish(seq)

            if not sched.running:
                if pending:
                    wait = max(0.0, pending[0].arrival - now())
                    before = now()
                    time.sleep(min(1e-3, wait))
                    if now() <= before:
                        # injected clock that doesn't advance with real time:
                        # fast-forward the trace instead of spinning forever
                        skip += max(wait, 1e-9)
                    continue
                if sched.queue:
                    # not necessarily a stall: if the last running sequence
                    # finished THIS iteration (a preemption replay whose
                    # final chunk completed it), admission ran earlier while
                    # still gated behind that prefill — retry before
                    # declaring the pool dead
                    seq = sched.admit_next()
                    if seq is None:
                        raise RuntimeError(
                            "queue stalled: page pool cannot admit any "
                            "request")
                    self._start_prefill(seq)
                    continue
                break

            sched.ensure_capacity()     # may preempt; victims re-enter later

            # decode the slots whose prefill is complete; mid-prefill slots
            # are masked to the null page so the fixed-shape step stays hot
            slots = [s for s in sched.running_slots()
                     if not self._prefill_pending(s)]
            if not slots:
                continue
            cache = sched.cache
            horizon = self.decode_steps
            if horizon > 1:
                # per-slot loop predicates for the multi-step dispatch,
                # built BEFORE snapshotting the page table: extend_capacity
                # appends best-effort horizon pages the compiled loop must
                # see. budget is the host scheduler's remaining allowance
                # (max-new and page-table capacity), restated as the
                # in-loop EXIT_BUDGET predicate; >= 1 because done
                # sequences were finished above.
                h_active = np.zeros((self.num_slots,), bool)
                h_budget = np.ones((self.num_slots,), np.int32)
                h_pages = np.zeros((self.num_slots,), np.int32)
                h_eos = np.full((self.num_slots,), -1, np.int32)
                for slot in slots:
                    seq = sched.running[slot]
                    req = seq.request
                    h_active[slot] = True
                    left = min(
                        req.max_new_tokens - len(seq.generated),
                        seq.max_context - len(req.prompt)
                        - len(seq.generated))
                    h_budget[slot] = left
                    h_pages[slot] = sched.extend_capacity(
                        slot, min(horizon, left))
                    if req.eos_id is not None:
                        h_eos[slot] = req.eos_id
            page_table, seq_lens = cache.page_table, cache.seq_lens
            if len(slots) != len(sched.running):
                page_table = page_table.copy()
                seq_lens = seq_lens.copy()
                for s in sched.running:
                    if self._prefill_pending(s):
                        page_table[s] = 0
                        seq_lens[s] = 0
            tokens = np.zeros((self.num_slots,), np.int32)
            for slot in slots:
                tokens[slot] = sched.running[slot].generated[-1]
            active = [sched.running[s].request.sampling for s in slots]
            sampled = any(not sp.greedy for sp in active)
            # skip the sampler's [S, V] filter sorts when no co-batched
            # request constrains the distribution (disabled filters are
            # exact no-ops, so variant choice never changes a draw)
            filtered = any(not sp.greedy and sp.filtered for sp in active)
            if sampled:
                # per-slot sampling params are constant while a request
                # occupies its slot; only rebuild + re-transfer the arrays
                # when the decoding composition changes (admission, finish,
                # preemption) — positions come from seq_lens on device
                comp = tuple((s, sched.running[s].request.sampling)
                             for s in slots)
                if comp != self._sampling_key:
                    seeds = np.zeros((self.num_slots,), np.uint32)
                    temps = np.zeros((self.num_slots,), np.float32)
                    top_ks = np.zeros((self.num_slots,), np.int32)
                    top_ps = np.ones((self.num_slots,), np.float32)
                    for slot in slots:
                        sp = sched.running[slot].request.sampling
                        seeds[slot] = sp.seed
                        temps[slot] = sp.temperature
                        top_ks[slot] = sp.top_k
                        top_ps[slot] = sp.top_p
                    self._sampling_args = tuple(jnp.asarray(a) for a in (
                        seeds, temps, top_ks, top_ps))
                    self._sampling_key = comp
                sampling_args = self._sampling_args
            else:
                sampling_args = self._null_sampling
            if horizon == 1:
                out = self._decode_fn(sampled, filtered)(
                    self.params, self.pools, jnp.asarray(page_table),
                    jnp.asarray(seq_lens), jnp.asarray(tokens),
                    *sampling_args)
                if self.sanitize:
                    next_tokens, self.pools, probe = out
                    check_finite_probe(probe, f"decode step {self.steps}")
                else:
                    next_tokens, self.pools = out
                self.steps += 1
                self.decode_dispatches += 1
                self.collective_bytes += \
                    self._tp_collective_bytes(self.num_slots)
                # jaxlint: allow[hot-host-sync] THE per-step sync:
                # continuous batching is host-driven — stop checks and slot
                # reuse need this step's tokens before the next batch can
                # be scheduled
                next_np = np.asarray(next_tokens)
                t_tok = now()
                for slot in slots:
                    seq = sched.running[slot]
                    cache.seq_lens[slot] += 1    # input token now cached
                    seq.generated.append(int(next_np[slot]))
                    seq.token_times.append(t_tok)
                    if seq.done:
                        finish(seq)
                continue

            # multi-step dispatch: up to `horizon` decode iterations run as
            # one compiled while_loop; the host resyncs once per dispatch
            # and replays the loop's effects (seq_lens advance, emitted
            # tokens, finish events) from the returned exit state
            out = self._decode_multi_fn(sampled, filtered)(
                self.params, self.pools, jnp.asarray(page_table),
                jnp.asarray(seq_lens), jnp.asarray(tokens),
                jnp.asarray(h_active), jnp.asarray(h_budget),
                jnp.asarray(h_pages), jnp.asarray(h_eos), *sampling_args)
            if self.sanitize:
                buf, n_steps, reasons, self.pools, probe = out
                check_finite_probe(
                    probe, f"multi-step decode dispatch "
                           f"{self.decode_dispatches} (horizon {horizon})")
            else:
                buf, n_steps, reasons, self.pools = out
            # THE per-horizon sync — the one intentional host round-trip
            # every `horizon` decode steps: the scheduler must replay the
            # loop's exit state (steps executed, tokens emitted, per-slot
            # exit reasons) before it can admit, preempt, or allocate
            # pages. max(1, ...) is for the recompile auditor's recorder,
            # which replays all-zero outputs; the real loop always executes
            # >= 1 iteration because the host guaranteed iteration 0's
            # predicates (ensure_capacity allocated the next page and done
            # sequences never reach the dispatch).
            # jaxlint: allow[hot-host-sync] the designed per-horizon sync
            k = max(1, int(n_steps))
            # jaxlint: allow[hot-host-sync] same designed per-horizon sync
            buf_np = np.asarray(buf)
            # jaxlint: allow[hot-host-sync] same designed per-horizon sync
            reasons_np = np.asarray(reasons)
            self.steps += k
            self.decode_dispatches += 1
            self.collective_bytes += \
                k * self._tp_collective_bytes(self.num_slots)
            for name, bit in (("eos", tf.EXIT_EOS),
                              ("token_budget", tf.EXIT_BUDGET),
                              ("page_budget", tf.EXIT_PAGES)):
                self.decode_exits[name] += \
                    int(((reasons_np[slots] & bit) != 0).sum())
            if k == horizon and not reasons_np[slots].any():
                self.decode_exits["horizon"] += 1
            t_tok = now()
            for slot in slots:
                seq = sched.running[slot]
                cache.seq_lens[slot] += k        # k input tokens now cached
                seq.generated.extend(int(t) for t in buf_np[:k, slot])
                seq.token_times.extend([t_tok] * k)
                if seq.done:
                    finish(seq)
        return results

    # ----------------------------------------------------------------- stats ----
    @property
    def live_kv_tokens(self) -> int:
        """Logical tokens resident across running sequences (seq_lens sum)."""
        return self.scheduler.cache.live_tokens

    @property
    def pages_in_use(self) -> int:
        """Distinct physical pages held — with prefix sharing this undercuts
        the logical page count (the dedup the README's memory math prices)."""
        return self.scheduler.allocator.used_count

    def trace_stats(self) -> Dict[str, int]:
        """Jit-cache accounting: ``variants`` is the number of static step
        variants traffic actually exercised, ``traces`` the total XLA traces
        behind them, and ``excess`` their difference — nonzero means some
        variant retraced after its first call (a shape or weak-type leak into
        the traced signature), exactly what the recompilation auditor
        (``repro.analysis.recompile``) and the benchmark gate pin to zero."""
        variants = len(self._jit_cache)
        traces = 0
        for fn in self._jit_cache.values():
            size = getattr(fn, "_cache_size", None)
            traces += int(size()) if size is not None else 1
        return {"variants": variants, "traces": traces,
                "excess": traces - variants}

    def tp_stats(self) -> Dict[str, object]:
        """Tensor-parallel accounting for the benchmark JSON.

        Page ids are global under head sharding, so every device holds (a
        1/tp-heads slice of) every in-use page: per-device *pages* equal the
        global count while per-device *bytes* divide by tp — times ``kv_rep``
        when tp > Hkv forces KV-head replication. Only attention layers hold
        pages; mamba layers instead carry the (replicated) per-slot SSM
        state, reported as ``ssm_state_bytes``. ``collective_bytes`` is the
        analytic per-device ring all-reduce wire traffic of the per-layer
        psums (attention out, MLP out / MoE combine).
        """
        arch = self.arch
        kinds = tf.layer_kinds(arch)
        nper = arch.num_layers // len(kinds)
        n_attn = sum(m == "attn" for m, _ in kinds) * nper
        n_mamba = len(kinds) * nper - n_attn
        page_bytes = (self.page_size * arch.num_kv_heads
                      * arch.resolved_head_dim
                      * 2 * n_attn * jnp.dtype(arch.dtype).itemsize)
        ssm_bytes = 0
        if n_mamba:
            from ..models import ssm as ssm_lib
            s = arch.ssm
            h = ssm_lib.num_ssm_heads(arch)
            ssm_bytes = n_mamba * self.num_slots * (
                h * s.state_dim * s.head_dim * 4          # fp32 SSD state
                + (s.conv_width - 1) * ssm_lib.conv_channels(arch)
                * jnp.dtype(arch.dtype).itemsize)         # conv tail
        return {
            "tp": self.tp,
            "kv_head_replication": self.kv_rep,
            "collective_bytes_per_device": self.collective_bytes,
            "per_device": {
                "pages_in_use": self.pages_in_use,
                "kv_bytes": self.pages_in_use * page_bytes * self.kv_rep
                // self.tp,
                "ssm_state_bytes": ssm_bytes,             # replicated
            },
        }
