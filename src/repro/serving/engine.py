"""ContinuousEngine: greedy serving with continuous batching.

Shapes the compiler sees are fixed — decode always runs the full
``num_slots`` batch against the same page pools and a [num_slots, max_pages]
page table — so requests join and leave mid-flight without recompiling.
Prefill runs per request (batch 1) at a page-aligned bucket length and its
dense K/V rows are scattered into freshly allocated pages; only the handful
of distinct bucket lengths ever trigger a compile.

The engine is deliberately greedy-only: parity with the static engine
(``repro.launch.serve --engine static``) must be exact, and greedy decode is
what makes recompute-preemption lossless.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tf
from ..models.model import Model
from .kv_cache import pages_needed
from .scheduler import Request, Scheduler, SequenceState

SERVABLE_FAMILIES = ("dense", "moe", "vlm")


class ContinuousEngine:
    def __init__(self, model: Model, params, *, num_slots: int = 8,
                 num_pages: int = 256, page_size: int = 16,
                 max_seq_len: int = 512):
        arch = model.arch
        assert arch.family in SERVABLE_FAMILIES, \
            f"continuous engine serves attention-only LMs, not {arch.family}"
        assert not arch.bidirectional and arch.num_heads > 0
        assert arch.pos_emb in ("rope", "mrope"), \
            "paged decode re-derives positions from seq_lens (rope/mrope only)"
        assert arch.window == 0, \
            "paged decode-attention has no sliding-window masking yet"
        self.model = model
        self.arch = arch
        self.params = params
        self.page_size = page_size
        self.num_slots = num_slots
        self.max_pages_per_seq = pages_needed(max_seq_len, page_size)
        self.scheduler = Scheduler(num_slots=num_slots, num_pages=num_pages,
                                   page_size=page_size,
                                   max_pages_per_seq=self.max_pages_per_seq)
        self.pools = tf.init_paged_caches(arch, num_pages, page_size,
                                          jnp.dtype(arch.dtype))
        self.steps = 0                  # decode steps executed (for stats)
        self.prefills = 0
        self._prefill_fns: Dict[int, object] = {}
        self._scatter_fns: Dict[int, object] = {}
        # donate the page pools through decode AND scatter: without it each
        # call copies every layer's [P, page, Hkv, D] pool to update a few rows
        self._donate_pools = jax.default_backend() in ("tpu", "gpu")
        donate = (1,) if self._donate_pools else ()
        self._decode = jax.jit(self._decode_impl, donate_argnums=donate)

    # ------------------------------------------------------------- jitted fns ---
    def _decode_impl(self, params, pools, page_table, seq_lens, tokens):
        """tokens [S] -> (greedy next token [S], new pools). S == num_slots.

        The argmax stays on device: the engine is greedy-only, so shipping
        [S, vocab] logits to the host every step would be pure transfer waste.
        """
        x = self.model._embed(params, tokens[:, None])
        x, pools = tf.paged_decode_stack(self.arch, params["blocks"], pools,
                                         x, page_table, seq_lens)
        logits = self.model._logits(params, x)[:, 0]
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), pools

    def _prefill_fn(self, bucket: int):
        fn = self._prefill_fns.get(bucket)
        if fn is None:
            def impl(params, caches, tokens, last_idx):
                x = self.model._embed(params, tokens)
                pos0 = jnp.zeros((1,), jnp.int32)
                x, caches = tf.decode_stack(self.arch, params["blocks"],
                                            caches, x, pos0)
                xl = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
                return self.model._logits(params, xl), caches
            fn = self._prefill_fns[bucket] = jax.jit(impl)
        return fn

    def _scatter_fn(self, n_pages: int):
        fn = self._scatter_fns.get(n_pages)
        if fn is None:
            page = self.page_size

            def impl(pools, caches, pids):
                def leaf(pool, dense):
                    if pool.ndim == 5:  # scanned stack: [nper, P, page, H, D]
                        nper, _, _, hk, dh = pool.shape
                        rows = dense.reshape(nper, n_pages, page, hk, dh)
                        return pool.at[:, pids].set(rows)
                    _, _, hk, dh = pool.shape
                    rows = dense.reshape(n_pages, page, hk, dh)
                    return pool.at[pids].set(rows)
                return jax.tree.map(leaf, pools, caches)
            donate = (0,) if self._donate_pools else ()
            fn = self._scatter_fns[n_pages] = jax.jit(impl,
                                                      donate_argnums=donate)
        return fn

    # --------------------------------------------------------------- prefill ----
    def _prefill_seq(self, seq: SequenceState) -> int:
        """Run prompt(+resumed tokens) prefill, scatter K/V into the
        sequence's pages, and return the first greedy token."""
        ctx = seq.context
        n_pages = pages_needed(len(ctx), self.page_size)
        bucket = n_pages * self.page_size
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :len(ctx)] = ctx
        dense_caches = self.model.init_caches(None, 1, bucket)
        logits, dense_caches = self._prefill_fn(bucket)(
            self.params, dense_caches, jnp.asarray(tokens),
            jnp.int32(len(ctx) - 1))
        pids = jnp.asarray(
            self.scheduler.cache.page_table[seq.slot, :n_pages])
        self.pools = self._scatter_fn(n_pages)(self.pools, dense_caches, pids)
        self.prefills += 1
        return int(np.argmax(np.asarray(logits[0, 0])))

    # ------------------------------------------------------------------- run ----
    def run(self, requests: Sequence[Request], *,
            time_fn=time.perf_counter) -> Dict[int, dict]:
        """Serve a trace to completion. Requests with ``arrival > 0`` are held
        back until the trace clock reaches them. Returns
        uid -> {"tokens", "token_times", "prompt_len"}."""
        sched = self.scheduler
        pending = deque(sorted(requests, key=lambda r: (r.arrival, r.uid)))
        results: Dict[int, dict] = {}
        t0 = time_fn()
        skip = 0.0                      # simulated idle time (frozen time_fn)

        def now() -> float:
            return time_fn() - t0 + skip

        def finish(seq: SequenceState) -> None:
            sched.finish(seq)
            results[seq.request.uid] = {
                "tokens": list(seq.generated),
                "token_times": list(seq.token_times),
                "prompt_len": len(seq.request.prompt),
            }

        while pending or sched.has_work:
            while pending and pending[0].arrival <= now():
                sched.submit(pending.popleft())

            # admit + prefill everything that fits right now. The prefill
            # argmax is always a *new* token: the first generation for a
            # fresh request, the continuation for a resumed preemption
            # (whose regenerated context is re-prefilled in one shot).
            while True:
                seq = sched.admit_next()
                if seq is None:
                    break
                seq.generated.append(self._prefill_seq(seq))
                seq.token_times.append(now())
                if seq.done:
                    finish(seq)

            if not sched.running:
                if pending:
                    wait = max(0.0, pending[0].arrival - now())
                    before = now()
                    time.sleep(min(1e-3, wait))
                    if now() <= before:
                        # injected clock that doesn't advance with real time:
                        # fast-forward the trace instead of spinning forever
                        skip += max(wait, 1e-9)
                    continue
                if sched.queue:
                    raise RuntimeError(
                        "queue stalled: page pool cannot admit any request")
                break

            sched.ensure_capacity()     # may preempt; victims re-enter later

            slots = sched.running_slots()
            if not slots:
                continue
            tokens = np.zeros((self.num_slots,), np.int32)
            for slot in slots:
                tokens[slot] = sched.running[slot].generated[-1]
            cache = sched.cache
            next_tokens, self.pools = self._decode(
                self.params, self.pools, jnp.asarray(cache.page_table),
                jnp.asarray(cache.seq_lens), jnp.asarray(tokens))
            self.steps += 1
            next_np = np.asarray(next_tokens)
            t_tok = now()
            for slot in slots:
                seq = sched.running[slot]
                cache.seq_lens[slot] += 1        # input token now cached
                seq.generated.append(int(next_np[slot]))
                seq.token_times.append(t_tok)
                if seq.done:
                    finish(seq)
        return results

    # ----------------------------------------------------------------- stats ----
    @property
    def live_kv_tokens(self) -> int:
        return self.scheduler.cache.live_tokens
