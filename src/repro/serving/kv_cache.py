"""Paged KV cache bookkeeping (host side).

The device side is the per-layer decode-state pytree built by
``repro.models.transformer.init_serving_state``: attention layers carry
[P, page_size, Hkv, Dh] page pools whose first axis is indexed by *physical
page id* (this module's domain); mamba layers carry constant-size per-slot
state that needs no page bookkeeping at all — a slot's state row is reset
on reuse and recomputed by forced-replay preemption. This module owns
everything about which pages belong to whom:

- ``PageAllocator``  : reference-counted free-list over physical ids 1..P-1
                       (page 0 is the null page — a write sink for inactive
                       slots, never owned by a sequence). A full page whose
                       K/V is shared by N sequences (prefix caching) is stored
                       once and carries N holds; it returns to the free list
                       only when the last hold drops.
- ``PagedCacheState``: per-slot page table + sequence length, mirrored as
                       numpy on the host (mutated cheaply every step) and
                       shipped to the device as two small int32 arrays.

Live KV memory is ``pages_in_use * page_size`` tokens instead of the dense
cache's ``num_slots * max_len`` — the memory math behind continuous batching —
and with prefix sharing the physical page count drops below the logical
``sum(seq_lens) / page_size`` (see README §Serving).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

NULL_PAGE = 0


def pages_needed(num_tokens: int, page_size: int) -> int:
    return -(-num_tokens // page_size)


class PageAllocator:
    """All-or-nothing, reference-counted free-list allocator over page ids.

    Page 0 is reserved (null page). ``alloc`` either returns exactly ``n``
    distinct pages (each with one hold) or None — admission control refuses
    rather than partially allocating. ``incref`` adds a hold to a live page
    (copy-on-write sharing); ``free`` drops one hold per page and recycles a
    page only when its last hold is gone.
    """

    def __init__(self, num_pages: int):
        assert num_pages >= 2, "need at least one real page beyond the null page"
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, NULL_PAGE, -1))
        self._refs: Dict[int, int] = {}

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        """Distinct live pages (shared pages count once — the dedup metric)."""
        return len(self._refs)

    def ref_count(self, page: int) -> int:
        return self._refs.get(page, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for pg in pages:
            self._refs[pg] = 1
        return pages

    def incref(self, page: int) -> None:
        if page == NULL_PAGE or page not in self._refs:
            raise ValueError(f"incref on unallocated page {page}")
        self._refs[page] += 1

    def free(self, pages: List[int]) -> None:
        """Drop one hold per page; recycle pages whose last hold dropped."""
        for pg in pages:
            if pg == NULL_PAGE or pg not in self._refs:
                raise ValueError(f"freeing unallocated page {pg}")
            self._refs[pg] -= 1
            if self._refs[pg] == 0:
                del self._refs[pg]
                self._free.append(pg)


@dataclasses.dataclass
class PagedCacheState:
    """Per-slot page-table/length state for a fixed decode batch."""

    num_slots: int
    max_pages_per_seq: int
    page_size: int

    def __post_init__(self):
        self.page_table = np.zeros((self.num_slots, self.max_pages_per_seq),
                                   np.int32)
        self.seq_lens = np.zeros((self.num_slots,), np.int32)

    # -- slot lifecycle ----------------------------------------------------------
    def assign(self, slot: int, pages: List[int], seq_len: int) -> None:
        assert self.seq_lens[slot] == 0 and not self.page_table[slot].any(), \
            f"slot {slot} not recycled"
        assert len(pages) <= self.max_pages_per_seq, (len(pages), slot)
        assert len(pages) >= pages_needed(seq_len, self.page_size)
        self.page_table[slot, :len(pages)] = pages
        self.seq_lens[slot] = seq_len

    def append_page(self, slot: int, page: int) -> None:
        row = self.page_table[slot]
        n = int((row != NULL_PAGE).sum())
        assert n < self.max_pages_per_seq, f"slot {slot} page table full"
        row[n] = page

    def release(self, slot: int) -> List[int]:
        """Clear a slot; returns its pages for the caller to free."""
        row = self.page_table[slot]
        pages = [int(p) for p in row[row != NULL_PAGE]]
        row[:] = NULL_PAGE
        self.seq_lens[slot] = 0
        return pages

    # -- queries -----------------------------------------------------------------
    def allocated_pages(self, slot: int) -> int:
        return int((self.page_table[slot] != NULL_PAGE).sum())

    def needs_page(self, slot: int) -> bool:
        """True if the *next* token's position falls past the allocated pages."""
        pos = int(self.seq_lens[slot])
        return pos // self.page_size >= self.allocated_pages(slot)

    @property
    def live_tokens(self) -> int:
        return int(self.seq_lens.sum())
