"""Continuous-batching serving engine over a paged KV cache.

- ``kv_cache``  : page-pool allocator + per-slot page-table/length state
- ``scheduler`` : request queue, admission by free-page count, slot recycling,
                  recompute-preemption on pool pressure
- ``engine``    : ``ContinuousEngine`` — fixed-shape jitted prefill/decode
                  steps driven by the scheduler, so requests join and leave
                  mid-flight without recompilation
"""
from .engine import ContinuousEngine
from .kv_cache import PageAllocator, PagedCacheState, pages_needed
from .scheduler import Request, Scheduler, SequenceState

__all__ = ["ContinuousEngine", "PageAllocator", "PagedCacheState",
           "pages_needed", "Request", "Scheduler", "SequenceState"]
