"""Continuous-batching serving engine over a paged KV cache.

- ``kv_cache``  : refcounted page-pool allocator + per-slot page-table/length
                  state (shared prefix pages are stored once)
- ``scheduler`` : request queue, admission by free-page count with anti-thrash
                  headroom, radix prefix index (page-aligned sharing + CoW
                  tails, LRU eviction), slot recycling, recompute-preemption
                  on pool pressure
- ``engine``    : ``ContinuousEngine`` — fixed-shape jitted chunked-prefill /
                  decode steps driven by the scheduler, so requests join and
                  leave mid-flight without recompilation and long prompts
                  never stall running decodes
"""
from .engine import ContinuousEngine
from .kv_cache import PageAllocator, PagedCacheState, pages_needed
from .scheduler import PrefixIndex, Request, Scheduler, SequenceState

__all__ = ["ContinuousEngine", "PageAllocator", "PagedCacheState",
           "PrefixIndex", "pages_needed", "Request", "Scheduler",
           "SequenceState"]
