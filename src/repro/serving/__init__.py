"""Continuous-batching serving engine over a paged KV cache.

- ``kv_cache``  : refcounted page-pool allocator + per-slot page-table/length
                  state (shared prefix pages are stored once)
- ``sampling``  : per-request ``SamplingParams`` and the shared on-device
                  sampler (temperature / top-k / top-p, (seed, position)
                  PRNG keys) both engines draw tokens from
- ``scheduler`` : request queue, admission by free-page count with anti-thrash
                  headroom, radix prefix index (page-aligned sharing + CoW
                  tails, LRU eviction), slot recycling, forced-replay
                  preemption on pool pressure (token-identical resume under
                  any sampling setting)
- ``engine``    : ``ContinuousEngine`` — fixed-shape jitted chunked-prefill /
                  decode steps driven by the scheduler, so requests join and
                  leave mid-flight without recompilation and long prompts
                  never stall running decodes. Layers plug in through a
                  per-layer decode-state protocol (paged KV pools for
                  attention mixers; pooled per-slot conv/SSD state for mamba
                  mixers), so dense, MoE, VLM, pure-SSM, and hybrid families
                  all serve on the same engine; ``tp > 1`` runs the steps
                  under shard_map on a 1-D mesh with head-sharded (or, at
                  tp > Hkv, head-replicated) page pools, Megatron
                  projections, and expert-parallel MoE (one psum per
                  attention/FFN output), token-identical to the
                  single-device engine
"""
from .engine import ContinuousEngine
from .kv_cache import PageAllocator, PagedCacheState, pages_needed
from .sampling import SamplingParams, sample_tokens
from .scheduler import PrefixIndex, Request, Scheduler, SequenceState

__all__ = ["ContinuousEngine", "PageAllocator", "PagedCacheState",
           "PrefixIndex", "pages_needed", "Request", "SamplingParams",
           "sample_tokens", "Scheduler", "SequenceState"]
