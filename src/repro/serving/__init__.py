"""Continuous-batching serving engine over a paged KV cache.

- ``kv_cache``  : refcounted page-pool allocator + per-slot page-table/length
                  state (shared prefix pages are stored once)
- ``sampling``  : per-request ``SamplingParams`` and the shared on-device
                  sampler (temperature / top-k / top-p, (seed, position)
                  PRNG keys) both engines draw tokens from
- ``scheduler`` : request queue, admission by free-page count with anti-thrash
                  headroom, radix prefix index (page-aligned sharing + CoW
                  tails, LRU eviction), slot recycling, forced-replay
                  preemption on pool pressure (token-identical resume under
                  any sampling setting)
- ``engine``    : ``ContinuousEngine`` — fixed-shape jitted chunked-prefill /
                  decode steps driven by the scheduler, so requests join and
                  leave mid-flight without recompilation and long prompts
                  never stall running decodes; ``tp > 1`` runs those steps
                  under shard_map on a 1-D mesh with head-sharded page pools
                  and Megatron projections (two all-reduces per layer),
                  token-identical to the single-device engine
"""
from .engine import ContinuousEngine
from .kv_cache import PageAllocator, PagedCacheState, pages_needed
from .sampling import SamplingParams, sample_tokens
from .scheduler import PrefixIndex, Request, Scheduler, SequenceState

__all__ = ["ContinuousEngine", "PageAllocator", "PagedCacheState",
           "PrefixIndex", "pages_needed", "Request", "SamplingParams",
           "sample_tokens", "Scheduler", "SequenceState"]
