"""Continuous-batching scheduler: admission, slot recycling, preemption.

Pure host logic (no jax): the engine asks the scheduler *what* to run each
step; the scheduler owns the request queue, the fixed pool of decode slots,
and the page allocator.

Policies
--------
admission   FIFO; a queued request is admitted when a slot is free AND the
            allocator can hand over the pages for its prompt plus one decode
            token. Memory is committed page-by-page afterwards, so admission
            tracks *actual* lengths, not worst-case ``max_len``.
growth      crossing a page boundary mid-decode allocates one page. If the
            pool is exhausted, the most recently admitted sequence is
            preempted (recompute-style: its pages are freed and it rejoins
            the front of the queue carrying the tokens generated so far —
            greedy decode regenerates the identical continuation).
recycling   EOS / max-new-tokens frees the slot and its pages in O(1); the
            next queued request takes the slot without touching the compiled
            decode step (fixed batch, inactive slots masked by seq_len 0).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

from .kv_cache import PageAllocator, PagedCacheState, pages_needed


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]                   # token ids
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival: float = 0.0                # seconds into the trace


@dataclasses.dataclass
class SequenceState:
    request: Request
    slot: int
    admit_order: int
    generated: List[int] = dataclasses.field(default_factory=list)
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def context(self) -> List[int]:
        """Tokens whose K/V must be in cache: prompt + generated so far."""
        return list(self.request.prompt) + self.generated

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.request.max_new_tokens:
            return True
        eos = self.request.eos_id
        return eos is not None and len(self.generated) > 0 \
            and self.generated[-1] == eos


class Scheduler:
    def __init__(self, *, num_slots: int, num_pages: int, page_size: int,
                 max_pages_per_seq: int):
        self.allocator = PageAllocator(num_pages)
        self.cache = PagedCacheState(num_slots, max_pages_per_seq, page_size)
        self.page_size = page_size
        self.queue: Deque[Request] = deque()
        self.running: Dict[int, SequenceState] = {}     # slot -> seq
        self._free_slots: List[int] = list(range(num_slots - 1, -1, -1))
        # uid -> (generated, token_times) carried across a preemption
        self._partial: Dict[int, tuple] = {}
        self._admit_counter = 0

    # ------------------------------------------------------------- submission ---
    def submit(self, request: Request) -> None:
        self.queue.append(request)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.running)

    # -------------------------------------------------------------- admission ---
    def admit_next(self) -> Optional[SequenceState]:
        """Admit the head-of-queue request if a slot and pages are available.

        Allocates pages for the full current context (prompt + any tokens a
        preempted sequence already generated) plus one decode token. Returns
        the SequenceState (prefill still owed by the engine) or None.
        """
        if not self.queue or not self._free_slots:
            return None
        req = self.queue[0]
        partial = self._partial.get(req.uid, ([], []))
        ctx_len = len(req.prompt) + len(partial[0])
        n_pages = pages_needed(ctx_len + 1, self.page_size)
        if n_pages > self.cache.max_pages_per_seq:
            raise ValueError(
                f"request {req.uid}: context {ctx_len} exceeds "
                f"max_pages_per_seq={self.cache.max_pages_per_seq}")
        pages = self.allocator.alloc(n_pages)
        if pages is None:
            return None
        self.queue.popleft()
        self._partial.pop(req.uid, None)
        slot = self._free_slots.pop()
        seq = SequenceState(req, slot, self._admit_counter,
                            generated=partial[0], token_times=partial[1])
        self._admit_counter += 1
        self.cache.assign(slot, pages, ctx_len)
        self.running[slot] = seq
        return seq

    # ----------------------------------------------------------------- growth ---
    def ensure_capacity(self) -> List[SequenceState]:
        """Allocate next-token pages for every running sequence, preempting
        (LIFO by admission) when the pool runs dry. Returns preempted seqs."""
        preempted: List[SequenceState] = []
        for slot in sorted(self.running):
            while self.cache.needs_page(slot):
                if slot not in self.running:
                    break               # preempted below while we iterated
                pages = self.allocator.alloc(1)
                if pages is not None:
                    self.cache.append_page(slot, pages[0])
                    continue
                victim = self._latest_running(exclude=slot)
                if victim is None:
                    raise RuntimeError(
                        "page pool too small for a single sequence: "
                        f"slot {slot} len {int(self.cache.seq_lens[slot])}")
                self._preempt(victim)
                preempted.append(victim)
        return preempted

    def _latest_running(self, exclude: int) -> Optional[SequenceState]:
        cands = [s for s in self.running.values() if s.slot != exclude]
        return max(cands, key=lambda s: s.admit_order) if cands else None

    def _preempt(self, seq: SequenceState) -> None:
        """Free the sequence's memory and put it back at the front of the
        queue; its generated-so-far tokens are kept and re-prefilled on
        re-admission (recompute preemption)."""
        self.allocator.free(self.cache.release(seq.slot))
        del self.running[seq.slot]
        self._free_slots.append(seq.slot)
        self._partial[seq.request.uid] = (seq.generated, seq.token_times)
        self.queue.appendleft(seq.request)

    # -------------------------------------------------------------- completion --
    def finish(self, seq: SequenceState) -> None:
        self.allocator.free(self.cache.release(seq.slot))
        del self.running[seq.slot]
        self._free_slots.append(seq.slot)

    # ------------------------------------------------------------------ views ---
    def running_slots(self) -> Sequence[int]:
        return sorted(self.running)
