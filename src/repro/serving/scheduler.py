"""Continuous-batching scheduler: admission, prefix cache, slot recycling,
preemption.

Pure host logic (no jax): the engine asks the scheduler *what* to run each
step; the scheduler owns the request queue, the fixed pool of decode slots,
the page allocator, and the prefix index.

Policies
--------
admission   FIFO; a queued request is admitted when a slot is free AND the
            allocator can hand over the pages for its prompt plus one decode
            token, leaving >= 1 free page of headroom whenever other
            sequences are running (otherwise the freshly prefilled admit is
            the first preemption victim the moment any neighbour grows —
            admit/preempt thrash). A request whose context cannot fit in
            ``max_pages_per_seq`` is rejected on its own (surfaced via
            ``take_rejected``) instead of killing the engine.
prefix      requests are matched against a hash-chained index of cached KV
            pages: the longest page-aligned prefix is shared (refcounted,
            stored once), a partially matching tail page is copied on
            divergence (CoW — the engine performs the device copy), and only
            the remaining suffix is prefilled. Index entries are evicted LRU
            (leaf-first) under pool pressure, before any preemption.
growth      crossing a page boundary mid-decode allocates one page. If the
            pool is exhausted (after evicting cached prefixes), the most
            recently admitted sequence is preempted (forced replay: its
            pages are freed and it rejoins the front of the queue carrying
            the tokens generated so far — on re-admission that context is
            re-prefilled *forced*, no token is re-decided, and the next
            token's (seed, position) PRNG key is the one the uninterrupted
            run would have used, so the continuation is token-identical
            under any sampling setting; the re-prefill typically prefix-hits
            the sequence's own surviving cached pages). Forced replay is
            also what makes preemption layer-kind-agnostic: a mamba mixer's
            per-slot recurrent state is never checkpointed — replaying the
            context recomputes it exactly, so the scheduler needs no
            per-kind state bookkeeping (engines serving SSM-bearing archs
            simply run with ``prefix_cache=False``; pages remain the
            admission/growth currency either way).
recycling   EOS / max-new-tokens frees the slot and its pages in O(1); the
            next queued request takes the slot without touching the compiled
            decode step (fixed batch, inactive slots masked by seq_len 0).
horizon     multi-step decode (engine ``decode_steps > 1``) pre-allocates
            up to a horizon's worth of pages per slot via
            ``extend_capacity`` BEFORE the dispatch: free pages only, never
            an eviction or preemption, and always leaving a reserve of
            ``(running - 1) + (1 if queued)`` free pages — so single-step
            preemption timing is unchanged and a starved pool degrades to
            shorter dispatches, not to new preemptions.

Slot lifecycle formula (the sanitizer re-checks it after every request):
a slot is either free (``seq_len == 0``, no pages, not in ``running``) or
owned by exactly one sequence, whose cache length is

    seq_len == prefill_target              while chunk-prefilling,
    seq_len == len(prompt) + len(generated) - 1   while decoding

(the -1: the newest token's KV is written by the step that consumes it),
and every allocated page is owned by exactly one slot or refcounted by the
prefix index — allocator free + owned + cached == num_pages, always.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from .kv_cache import PageAllocator, PagedCacheState, pages_needed
from .sampling import SamplingParams


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]                   # token ids
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival: float = 0.0                # seconds into the trace
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)     # greedy unless asked otherwise


@dataclasses.dataclass
class SequenceState:
    request: Request
    slot: int
    admit_order: int
    generated: List[int] = dataclasses.field(default_factory=list)
    token_times: List[float] = dataclasses.field(default_factory=list)
    cached_len: int = 0        # context tokens served from the prefix cache
    prefilled: int = 0         # context tokens whose K/V is in pages so far
    prefill_target: int = 0    # context length at admission (prefill is done
                               # when prefilled reaches it; ``context`` itself
                               # keeps growing as tokens are generated)
    max_context: int = 1 << 30  # page-table capacity in tokens (set at
                                # admission): generation is truncated here
                                # rather than overflowing the page table
    cow: Optional[Tuple[int, int]] = None   # (src_page, dst_page) to copy

    @property
    def context(self) -> List[int]:
        """Tokens whose K/V must be in cache: prompt + generated so far."""
        return list(self.request.prompt) + self.generated

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.request.max_new_tokens:
            return True
        if len(self.request.prompt) + len(self.generated) >= self.max_context:
            return True                 # cache capacity: truncate gracefully
        eos = self.request.eos_id
        return eos is not None and len(self.generated) > 0 \
            and self.generated[-1] == eos


_ROOT = -1          # parent "page id" of level-0 edges (no page is -1)

_EdgeKey = Tuple[int, Tuple[int, ...]]      # (parent page id, page's tokens)


@dataclasses.dataclass
class _CachedPage:
    """One radix edge: a physical page holding K/V for ``key[1]`` (this
    page's token slice), hanging off the parent *page* ``key[0]``."""
    key: _EdgeKey
    parent_key: Optional[_EdgeKey]          # None for level-0 edges
    page: int
    last_used: int
    children: int = 0


class PrefixIndex:
    """Radix index over cached KV pages.

    Full pages form a tree whose edges are keyed by (parent page id, this
    page's ``page_size`` tokens): a physical page id is unique while the
    index holds it, so the pair is a real radix edge — matching a k-page
    prefix is k dict hits of O(page_size) keys, and memory is linear in the
    cached token count (not quadratic, as keying by the whole prefix would
    be). Partial tail pages (< page_size tokens) are kept per parent node
    and matched by longest common prefix; a hit is served copy-on-write.

    The index holds one allocator reference per entry, so cached pages
    survive the sequences that wrote them; ``evict_one`` drops LRU leaves
    when the pool needs pages back.
    """

    def __init__(self, allocator: PageAllocator, page_size: int,
                 max_partials_per_node: int = 4):
        self.allocator = allocator
        self.page_size = page_size
        self.max_partials_per_node = max_partials_per_node
        self._full: Dict[_EdgeKey, _CachedPage] = {}
        # parent page id -> {tail tokens -> entry}
        self._partials: Dict[int, Dict[Tuple[int, ...], _CachedPage]] = {}
        # page id -> number of index entries holding it, maintained
        # incrementally at entry creation/removal (the same physical page can
        # carry both a partial entry and a later full entry). Rebuilding this
        # map per evict_one()/reclaimable() call made eviction bursts O(pages
        # freed * index entries).
        self._holds: Dict[int, int] = {}
        self._clock = 0
        self.hits = 0
        self.misses = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _hold(self, page: int) -> None:
        self._holds[page] = self._holds.get(page, 0) + 1

    def _unhold(self, page: int) -> None:
        n = self._holds[page] - 1
        if n:
            self._holds[page] = n
        else:
            del self._holds[page]

    @property
    def num_entries(self) -> int:
        return len(self._full) + sum(len(b) for b in self._partials.values())

    def reclaimable(self) -> int:
        """Pages that evicting index entries would actually free right now:
        those whose every allocator hold belongs to the index (no running
        sequence shares them)."""
        return sum(1 for p, n in self._holds.items()
                   if self.allocator.ref_count(p) == n)

    # ------------------------------------------------------------------ match ---
    def match(self, tokens: Sequence[int]
              ) -> Tuple[List[int], Optional[Tuple[int, int]]]:
        """Longest chain of cached full pages matching ``tokens``, plus an
        optional partially matching tail ``(page, lcp_tokens)``. Does not
        take references — the caller pins what it keeps."""
        pages: List[int] = []
        parent = _ROOT
        n = 0
        while (n + 1) * self.page_size <= len(tokens):
            e = self._full.get(
                (parent, tuple(tokens[n * self.page_size:
                                      (n + 1) * self.page_size])))
            if e is None:
                break
            e.last_used = self._tick()
            pages.append(e.page)
            parent = e.page
            n += 1
        rest = tuple(tokens[n * self.page_size:])
        best: Optional[_CachedPage] = None
        best_lcp = 0
        for tail_toks, e in self._partials.get(parent, {}).items():
            lcp = 0
            for a, b in zip(tail_toks, rest):
                if a != b:
                    break
                lcp += 1
            if lcp > best_lcp:
                best, best_lcp = e, lcp
        if best is not None:
            best.last_used = self._tick()
        if pages or best is not None:
            self.hits += 1
        else:
            self.misses += 1
        return pages, (best.page, best_lcp) if best is not None else None

    # ----------------------------------------------------------------- insert ---
    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> None:
        """Register the pages holding K/V for ``tokens`` (page i covers
        tokens[i*page : (i+1)*page]). Existing entries win — the same logical
        prefix re-prefilled into different physical pages is already cached —
        and deeper levels chain off the *index's* page, so the tree stays one
        connected radix structure."""
        parent, parent_key = _ROOT, None
        n_full = len(tokens) // self.page_size
        for i in range(n_full):
            key = (parent,
                   tuple(tokens[i * self.page_size:(i + 1) * self.page_size]))
            e = self._full.get(key)
            if e is None:
                self.allocator.incref(pages[i])
                self._hold(pages[i])
                e = _CachedPage(key=key, parent_key=parent_key,
                                page=pages[i], last_used=self._tick())
                self._full[key] = e
                if parent_key is not None:
                    self._full[parent_key].children += 1
            else:
                e.last_used = self._tick()
            parent, parent_key = e.page, e.key
        rem = tuple(tokens[n_full * self.page_size:])
        if not rem or n_full >= len(pages):
            return
        bucket = self._partials.setdefault(parent, {})
        if rem in bucket:
            bucket[rem].last_used = self._tick()
            return
        if len(bucket) >= self.max_partials_per_node:
            lru = min(bucket, key=lambda t: bucket[t].last_used)
            self._drop_partial(parent, lru)
        self.allocator.incref(pages[n_full])
        self._hold(pages[n_full])
        bucket[rem] = _CachedPage(key=(parent, rem), parent_key=parent_key,
                                  page=pages[n_full], last_used=self._tick())
        if parent_key is not None:
            self._full[parent_key].children += 1

    # --------------------------------------------------------------- eviction ---
    def _drop_partial(self, parent: int, tail: Tuple[int, ...]) -> None:
        e = self._partials[parent].pop(tail)
        if not self._partials[parent]:
            del self._partials[parent]
        if e.parent_key is not None:
            self._full[e.parent_key].children -= 1
        self._unhold(e.page)
        self.allocator.free([e.page])

    def evict_one(self) -> bool:
        """Evict a *leaf* entry (a page no longer on any cached chain's
        interior — evicting interiors first would orphan ref-held
        descendants), preferring LRU among leaves whose page would actually
        return to the free list: dropping an entry for a page a running
        sequence still shares frees nothing and just destroys cache later
        requests would hit. Non-reclaimable leaves go only when no
        reclaimable leaf exists (to unblock reclaimable interiors behind
        them). Returns False when the index is empty."""
        best: Optional[_CachedPage] = None
        fallback: Optional[_CachedPage] = None
        best_partial = fallback_partial = None
        for e in self._full.values():
            if e.children != 0:
                continue
            if self.allocator.ref_count(e.page) == self._holds[e.page]:
                if best is None or e.last_used < best.last_used:
                    best, best_partial = e, None
            elif fallback is None or e.last_used < fallback.last_used:
                fallback, fallback_partial = e, None
        for parent, bucket in self._partials.items():
            for tail, e in bucket.items():
                if self.allocator.ref_count(e.page) == self._holds[e.page]:
                    if best is None or e.last_used < best.last_used:
                        best, best_partial = e, (parent, tail)
                elif fallback is None or e.last_used < fallback.last_used:
                    fallback, fallback_partial = e, (parent, tail)
        if best is None:
            best, best_partial = fallback, fallback_partial
        if best is None:
            return False
        if best_partial is not None:
            self._drop_partial(*best_partial)
            return True
        del self._full[best.key]
        if best.parent_key is not None:
            self._full[best.parent_key].children -= 1
        self._unhold(best.page)
        self.allocator.free([best.page])
        return True


class Scheduler:
    def __init__(self, *, num_slots: int, num_pages: int, page_size: int,
                 max_pages_per_seq: int, prefix_cache: bool = False):
        self.allocator = PageAllocator(num_pages)
        self.cache = PagedCacheState(num_slots, max_pages_per_seq, page_size)
        self.page_size = page_size
        self.prefix: Optional[PrefixIndex] = (
            PrefixIndex(self.allocator, page_size) if prefix_cache else None)
        self.queue: Deque[Request] = deque()
        self.running: Dict[int, SequenceState] = {}     # slot -> seq
        self.rejected: List[Request] = []
        self._free_slots: List[int] = list(range(num_slots - 1, -1, -1))
        # uid -> (generated, token_times) carried across a preemption
        self._partial: Dict[int, tuple] = {}
        self._admit_counter = 0

    # ------------------------------------------------------------- submission ---
    def submit(self, request: Request) -> None:
        self.queue.append(request)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.running)

    def take_rejected(self) -> List[Request]:
        out, self.rejected = self.rejected, []
        return out

    # -------------------------------------------------------------- admission ---
    def admit_next(self) -> Optional[SequenceState]:
        """Admit the head-of-queue request if a slot and pages are available.

        Matches the longest cached page-aligned prefix (sharing those pages),
        schedules a CoW copy for a partially matching tail page, and
        allocates fresh pages for the rest of the context (prompt + any
        tokens a preempted sequence already generated) plus one decode token.
        Returns the SequenceState (suffix prefill still owed by the engine)
        or None. Requests that can never fit are dropped into ``rejected``
        and admission moves on to the next request.
        """
        while self.queue and self._free_slots:
            # cheap pre-check before the radix walk: even a full prefix hit
            # needs one fresh page (plus headroom) — when nothing is
            # obtainable, skip the per-iteration match/incref/undo churn a
            # blocked head request would otherwise repeat every decode step
            # (reclaimable() scans the index, so consult it only when the
            # free list alone is short)
            need_min = 1 + (1 if self.running else 0)
            if self.allocator.free_count < need_min and (
                    self.prefix is None
                    or self.allocator.free_count + self.prefix.reclaimable()
                    < need_min):
                return None
            req = self.queue[0]
            partial = self._partial.get(req.uid, ([], []))
            ctx = list(req.prompt) + partial[0]
            ctx_len = len(ctx)
            n_pages = pages_needed(ctx_len + 1, self.page_size)
            if n_pages > self.cache.max_pages_per_seq:
                # reject this one request; keep serving the rest
                self.queue.popleft()
                self._partial.pop(req.uid, None)
                self.rejected.append(req)
                continue

            matched: List[int] = []
            tail: Optional[Tuple[int, int]] = None
            if self.prefix is not None:
                matched, tail = self.prefix.match(ctx)
                while matched and len(matched) * self.page_size >= ctx_len:
                    matched.pop()       # always leave >= 1 token to prefill
                    tail = None         # its parent chain just shrank
                for pg in matched:
                    self.allocator.incref(pg)
                if tail is not None:
                    lcp = min(tail[1],
                              ctx_len - len(matched) * self.page_size - 1)
                    if lcp <= 0:
                        tail = None
                    else:
                        self.allocator.incref(tail[0])  # pin the CoW source
                        tail = (tail[0], lcp)

            n_fresh = n_pages - len(matched)
            # anti-thrash headroom: never admit into a pool so tight that the
            # first neighbour to grow immediately preempts this admission
            pages = self._alloc_with_eviction(
                n_fresh, reserve=1 if self.running else 0)
            if pages is None:
                if matched:
                    self.allocator.free(matched)
                if tail is not None:
                    self.allocator.free([tail[0]])
                return None

            self.queue.popleft()
            self._partial.pop(req.uid, None)
            slot = self._free_slots.pop()
            seq = SequenceState(req, slot, self._admit_counter,
                                generated=partial[0], token_times=partial[1])
            self._admit_counter += 1
            seq.cached_len = len(matched) * self.page_size
            if tail is not None:
                seq.cow = (tail[0], pages[0])
                seq.cached_len += tail[1]
            seq.prefilled = seq.cached_len
            seq.prefill_target = ctx_len
            # a request whose generation would outgrow the page table ends
            # at capacity instead of asserting out of append_page mid-trace
            seq.max_context = self.cache.max_pages_per_seq * self.page_size
            self.cache.assign(slot, matched + pages, ctx_len)
            self.running[slot] = seq
            return seq
        return None

    def cow_done(self, seq: SequenceState) -> None:
        """The engine copied the CoW tail page; drop the pin on the source."""
        if seq.cow is not None:
            self.allocator.free([seq.cow[0]])
            seq.cow = None

    def register_prefix(self, slot: int, tokens: Sequence[int]) -> None:
        """Publish the slot's pages covering ``tokens`` into the prefix index
        (called after prefill and again when a sequence finishes)."""
        if self.prefix is None or not tokens:
            return
        npg = pages_needed(len(tokens), self.page_size)
        row = [int(p) for p in self.cache.page_table[slot, :npg]]
        self.prefix.insert(list(tokens), row)

    # ----------------------------------------------------------------- growth ---
    def _alloc_with_eviction(self, n: int, reserve: int = 0
                             ) -> Optional[List[int]]:
        """Allocate ``n`` pages, evicting cached prefixes as needed; refuses
        unless ``reserve`` pages would still be free afterwards. Eviction only
        starts when it can actually reach the target — a doomed attempt must
        not strip the index (destroying cached K/V other requests will hit)
        just to fail anyway."""
        target = n + reserve
        if self.allocator.free_count < target and self.prefix is not None \
                and self.allocator.free_count + self.prefix.reclaimable() \
                >= target:
            while self.allocator.free_count < target \
                    and self.prefix.evict_one():
                pass
        if self.allocator.free_count < target:
            return None
        return self.allocator.alloc(n)

    def extend_capacity(self, slot: int, horizon: int) -> int:
        """Best-effort page pre-allocation so ``slot`` can absorb up to
        ``horizon`` more decode tokens without a host resync (the multi-step
        compiled decode loop's page budget). Takes only *free* pages — never
        evicts the prefix index, never preempts, so single-step allocation
        behavior (and preemption timing) is unchanged when the pool runs
        tight — and leaves one free page per other running sequence (plus
        one for the admission queue) so a horizon grab cannot starve a
        neighbour's next-token growth into a preemption that ``horizon=1``
        would not have caused. Returns the slot's resulting token capacity
        (allocated pages x page size): the in-loop write limit the compiled
        loop early-exits on."""
        cache = self.cache
        want = min(pages_needed(int(cache.seq_lens[slot]) + horizon,
                                self.page_size),
                   cache.max_pages_per_seq)
        reserve = max(len(self.running) - 1, 0) + (1 if self.queue else 0)
        while cache.allocated_pages(slot) < want \
                and self.allocator.free_count > reserve:
            pages = self.allocator.alloc(1)
            if pages is None:
                break
            cache.append_page(slot, pages[0])
        return cache.allocated_pages(slot) * self.page_size

    def ensure_capacity(self) -> List[SequenceState]:
        """Allocate next-token pages for every running sequence, evicting
        cached prefixes and then preempting (LIFO by admission) when the pool
        runs dry. Returns preempted seqs."""
        preempted: List[SequenceState] = []
        for slot in sorted(self.running):
            while slot in self.running and self.cache.needs_page(slot):
                pages = self._alloc_with_eviction(1)
                if pages is not None:
                    self.cache.append_page(slot, pages[0])
                    continue
                victim = self._latest_running(exclude=slot)
                if victim is None:
                    raise RuntimeError(
                        "page pool too small for a single sequence: "
                        f"slot {slot} len {int(self.cache.seq_lens[slot])}")
                self._preempt(victim)
                preempted.append(victim)
        return preempted

    def _latest_running(self, exclude: int) -> Optional[SequenceState]:
        cands = [s for s in self.running.values() if s.slot != exclude]
        return max(cands, key=lambda s: s.admit_order) if cands else None

    def _preempt(self, seq: SequenceState) -> None:
        """Free the sequence's memory and put it back at the front of the
        queue; its generated-so-far tokens are kept and re-prefilled as
        *forced* context on re-admission (forced-replay preemption: nothing
        is re-decided, and the next token's (seed, position) sampling key is
        unchanged, so the resumed stream is token-identical even at
        temperature > 0 — and cheap when its prompt pages survive in the
        prefix index)."""
        self.allocator.free(self.cache.release(seq.slot))
        del self.running[seq.slot]
        self._free_slots.append(seq.slot)
        self._partial[seq.request.uid] = (seq.generated, seq.token_times)
        self.queue.appendleft(seq.request)

    # -------------------------------------------------------------- completion --
    def finish(self, seq: SequenceState) -> None:
        self.allocator.free(self.cache.release(seq.slot))
        del self.running[seq.slot]
        self._free_slots.append(seq.slot)

    # ------------------------------------------------------------------ views ---
    def running_slots(self) -> Sequence[int]:
        return sorted(self.running)
