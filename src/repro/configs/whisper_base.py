"""whisper-base [audio] — arXiv:2212.04356 (unverified).

6L (enc) + 6L (dec), d_model=512 8H (MHA) d_ff=2048 vocab=51865, head_dim=64.
Encoder-decoder; the conv audio frontend is a STUB per the assignment —
``input_specs()`` provides precomputed frame embeddings of shape
(batch, enc_seq_len=1500, d_model).

Note: whisper's natural decoder length is 448; the grid's 32k decode cells are
configuration exercises for the serving path (noted in DESIGN.md).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,              # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2_048,
    vocab_size=51_865,         # padded to a multiple of 128 inside the embed layer
    head_dim=64,
    mlp="gelu",
    norm="layernorm",
    pos_emb="sinusoidal",
    use_bias=True,
    tie_embeddings=True,
    enc_layers=6,
    enc_seq_len=1_500,
    frontend="audio_stub",
)
