"""qwen2-vl-2b [vlm] — arXiv:2409.12191.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, head_dim=128.
M-RoPE (multimodal rotary: temporal/height/width position triplets) on the text
backbone; the vision patch frontend is a STUB per the assignment — patch embeddings
arrive pre-computed and positions arrive as (3, batch, seq) M-RoPE ids.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1_536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8_960,
    vocab_size=151_936,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    pos_emb="mrope",
    rope_theta=1_000_000.0,
    use_bias=True,             # qwen2 uses bias on qkv projections
    tie_embeddings=True,
    frontend="vision_stub",
)
