"""llama3.2-3b [dense] — hf:meta-llama/Llama-3.2 family (unverified).

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256, head_dim=128.
24 query heads are NOT divisible by the 16-way model axis — this arch is why tensor
parallelism in this framework shards fused feature dims (q_dim=3072, kv_dim=1024)
instead of head counts (see parallel/sharding.py).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3_072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8_192,
    vocab_size=128_256,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    rope_theta=500_000.0,
    use_bias=False,
    tie_embeddings=True,
)
