"""jamba-v0.1-52b [hybrid] — arXiv:2403.19887.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16 experts top-2.
Mamba:attention 7:1 interleave (one attention layer per 8-layer period, at period
index 4 as in the released model); MoE every other layer starting at layer 1.

Runs the long_500k cell: only 4 of 32 layers are attention, each holding a KV cache
that is read linearly per decoded token; the 28 Mamba layers carry constant-size state.
"""
from .base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4_096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=65_536,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    pos_emb="none",            # jamba uses no positional encoding (mamba provides order)
    use_bias=False,
    hybrid_period=8,
    hybrid_attn_index=4,
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        num_shared_experts=0,
        expert_ff=14_336,
        capacity_factor=1.25,
        every=2,
        first=1,
    ),
    ssm=SSMConfig(
        state_dim=16,          # jamba uses mamba-1 style small state
        head_dim=64,
        expand=2,
        chunk=256,
        conv_width=4,
        ngroups=1,
    ),
)
