"""command-r-35b [dense] — hf:CohereForAI/c4ai-command-r-v01 (unverified).

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000 — GQA, no-bias linears.
Cohere uses plain LayerNorm (no bias) and a large 256k vocabulary, which makes this
arch the embedding/logits-sharding stress test of the grid.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8_192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22_528,
    vocab_size=256_000,
    head_dim=128,
    mlp="swiglu",
    norm="layernorm",
    pos_emb="rope",
    rope_theta=8_000_000.0,
    use_bias=False,
    tie_embeddings=True,
)
