"""The assignment's four input-shape cells + ShapeDtypeStruct input specs.

``train_*``    lower ``train_step`` (tokens + targets).
``prefill_*``  lower ``prefill_step`` (tokens -> logits + KV cache).
``decode_*``   lower ``serve_step`` (one new token against a seq_len KV cache).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .base import ArchConfig, ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode")

SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}

# Archs allowed to run the long_500k cell (sub-quadratic sequence mixing).
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def cell_supported(arch: ArchConfig, shape: ShapeConfig) -> str:
    """'' if the (arch, shape) cell runs; otherwise a skip reason."""
    if shape.name == "long_500k" and arch.family not in LONG_CONTEXT_FAMILIES:
        return "SKIP(full-attention: long_500k needs sub-quadratic sequence mixing)"
    return ""


def token_spec(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    No device memory is allocated — these feed ``jax.jit(...).lower()`` only.
    """
    b, s = shape.global_batch, shape.seq_len
    act = jnp.dtype(arch.dtype)
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = token_spec((b, s))
        specs["targets"] = token_spec((b, s))
        specs["loss_mask"] = jax.ShapeDtypeStruct((b, s), act)
        if arch.family == "encdec":
            # frontend stub: precomputed frame embeddings for the encoder
            specs["frontend_embeddings"] = jax.ShapeDtypeStruct(
                (b, arch.enc_seq_len, arch.d_model), act)
        if arch.frontend == "vision_stub":
            # a fixed budget of patch embeddings prepended to the text sequence is
            # modeled as part of the sequence itself; positions arrive via M-RoPE ids
            specs["mrope_positions"] = token_spec((3, b, s))
    elif shape.kind == "prefill":
        specs["tokens"] = token_spec((b, s))
        if arch.family == "encdec":
            specs["frontend_embeddings"] = jax.ShapeDtypeStruct(
                (b, arch.enc_seq_len, arch.d_model), act)
        if arch.frontend == "vision_stub":
            specs["mrope_positions"] = token_spec((3, b, s))
    elif shape.kind == "decode":
        # one new token per sequence; the cache itself is threaded through the step
        # as state (see train.steps.make_serve_step) and is part of in_shardings.
        specs["tokens"] = token_spec((b, 1))
        specs["positions"] = token_spec((b,))
        if arch.frontend == "vision_stub":
            specs["mrope_positions"] = token_spec((3, b, 1))
    else:
        raise ValueError(shape.kind)
    return specs
