"""llama4-maverick-400b-a17b [moe] — hf:meta-llama/Llama-4 family (unverified).

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts top-1,
interleaved (every other layer MoE, one shared expert on MoE layers).

At ~400B total parameters this arch is the memory-capacity stress test: fp32 LAMB
states are 3.2 TB and require ZeRO-1 sharding over the data axis (the paper's own
citation [60]) to fit 16 GB/chip on the 16x16 pod — the dry-run's memory_analysis
proves it.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5_120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8_192,
    vocab_size=202_048,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    rope_theta=500_000.0,
    use_bias=False,
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        num_shared_experts=1,
        expert_ff=8_192,
        capacity_factor=1.25,
        every=2,          # interleaved MoE: odd layers routed, even layers dense
        first=1,
    ),
)
