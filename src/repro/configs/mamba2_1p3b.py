"""mamba2-1.3b [ssm] — arXiv:2405.21060 (state-space duality, unverified).

48L d_model=2048 (attention-free), vocab=50280, ssm_state=128.
SSD inner dim = 2*d_model = 4096, head_dim=64 -> 64 SSD heads; chunked scan with
chunk=256 turns the recurrence into MXU-friendly batched GEMMs (the TPU adaptation of
the paper's "not all GEMMs are equal": SSD chunk GEMMs are the skinny ones here).

Runs the long_500k cell: the recurrent state is O(heads * head_dim * state) regardless
of context length.
"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2_048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,         # pads to 50304, the standard GPT-NeoX padding
    mlp="swiglu",
    norm="rmsnorm",
    pos_emb="none",
    use_bias=False,
    tie_embeddings=True,
    ssm=SSMConfig(
        state_dim=128,
        head_dim=64,
        expand=2,
        chunk=256,
        conv_width=4,
        ngroups=1,
    ),
)
