"""deepseek-moe-16b [moe] — arXiv:2401.06066.

28L d_model=2048 16H (MHA, kv=16) per-expert d_ff=1408 vocab=102400,
fine-grained MoE: 64 routed experts top-6 + 2 shared experts.

Paper-characterization relevance: total params (~16B) >> active params (~2.8B), so the
LAMB optimizer reads/writes 4x *total* model size while step FLOPs track *active*
params — Takeaway 8 (memory-intensity of the optimizer) is amplified ~6x vs dense.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2_048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1_408,
    vocab_size=102_400,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    use_bias=False,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        num_shared_experts=2,
        expert_ff=1_408,
        capacity_factor=1.25,
        every=1,
        first=0,
    ),
)
