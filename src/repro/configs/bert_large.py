"""bert-large — the paper's own model (Devlin et al. 2018; Table 2 hyperparameters).

24L d_model=1024 16H (MHA) d_ff=4096 vocab=30522, learned positions, GeLU MLP,
post-LayerNorm blocks, biases everywhere, tied MLM head. Pre-training shapes are the
paper's Phase-1 (n=128) and Phase-2 (n=512) at mini-batch 4..32 — see
benchmarks.breakdown which reproduces Figure 4 cells Ph{1,2}-B{4,32}-FP{32,16}.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="bert-large",
    family="dense",
    num_layers=24,
    d_model=1_024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4_096,
    vocab_size=30_522,
    head_dim=64,
    mlp="gelu",
    norm="layernorm",
    pos_emb="learned",
    use_bias=True,
    tie_embeddings=True,
    post_norm=True,
    bidirectional=True,
    mlm_transform=True,
    max_position=512,
)
