"""Architecture & run configuration dataclasses.

Every assigned architecture is expressed as an :class:`ArchConfig`. The config is a
plain frozen dataclass (hashable, usable as a jit static argument) and intentionally
carries *everything* a model needs — there is no hidden global state.

Families
--------
``dense``    decoder-only transformer (GQA attention + SwiGLU/GeLU MLP)
``moe``      dense skeleton with the MLP replaced by a token-choice MoE
``ssm``      attention-free Mamba-2 (SSD) stack
``hybrid``   interleaved attention/Mamba-2 blocks (+ optional MoE), e.g. Jamba
``encdec``   encoder-decoder transformer (Whisper); frontend stubbed
``vlm``      decoder-only backbone with M-RoPE (Qwen2-VL); vision frontend stubbed
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Token-choice MoE sub-config."""

    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    expert_ff: int = 0              # per-expert intermediate dim (0 -> use arch d_ff)
    capacity_factor: float = 1.25
    # every `every` layers one MoE layer; 1 == every layer is MoE
    every: int = 1
    # index offset of the first MoE layer
    first: int = 0
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD, arXiv:2405.21060) sub-config."""

    state_dim: int = 128            # N — SSM state size per head
    head_dim: int = 64              # P — channels per SSD head
    expand: int = 2                 # inner dim = expand * d_model
    chunk: int = 256                # SSD chunk length
    conv_width: int = 4             # depthwise causal conv width
    ngroups: int = 1                # B/C groups (GVA-style)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """A complete, paper-faithful architecture description."""

    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int                  # query heads (0 for attention-free)
    num_kv_heads: int               # GQA kv heads
    d_ff: int                       # MLP intermediate (per expert for fine-grained MoE)
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    # --- nonlinearity / block style ------------------------------------------------
    mlp: str = "swiglu"             # swiglu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    pos_emb: str = "rope"           # rope | mrope | learned | sinusoidal | none
    rope_theta: float = 10_000.0
    use_bias: bool = False
    tie_embeddings: bool = False
    # --- family payloads -------------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: period and which index within the period is attention (Jamba: 1 attn per
    # 8 layers, at index 4 of each period by convention)
    hybrid_period: int = 0
    hybrid_attn_index: int = 0
    # encoder (encdec only)
    enc_layers: int = 0
    enc_seq_len: int = 0            # encoder frames per example (whisper: 1500)
    # frontends (audio/vision) are stubs: inputs arrive as precomputed embeddings
    frontend: str = "none"          # none | audio_stub | vision_stub
    # --- numerics ----------------------------------------------------------------
    dtype: str = "bfloat16"         # compute/activation dtype
    param_dtype: str = "float32"    # master parameter dtype
    # --- attention impl ------------------------------------------------------------
    attn_impl: str = "chunked"      # naive | chunked | flash
    attn_chunk: int = 1024          # kv-block for chunked/flash attention
    # sliding-window attention (0 = full); used beyond-paper for long-context cells
    window: int = 0
    # --- block style variants --------------------------------------------------
    post_norm: bool = False         # BERT-style post-LN blocks
    bidirectional: bool = False     # encoder-only attention (BERT); no decode step
    mlm_transform: bool = False     # BERT MLM output head (dense+gelu+LN)
    max_position: int = 512         # learned-position table size
    # --- training ------------------------------------------------------------------
    remat: bool = True
    scan_layers: bool = True
    logit_softcap: float = 0.0

    # ------------------------------------------------------------------ helpers ---
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads == 0:
            return 0
        return self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    def is_attention_layer(self, layer_idx: int) -> bool:
        """For hybrid stacks: does layer ``layer_idx`` use attention?"""
        if self.family in ("dense", "moe", "encdec", "vlm"):
            return True
        if self.family == "ssm":
            return False
        assert self.hybrid_period > 0
        return layer_idx % self.hybrid_period == self.hybrid_attn_index

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        m = self.moe
        return layer_idx >= m.first and (layer_idx - m.first) % m.every == 0

    # -- parameter counting (used for roofline MODEL_FLOPS = 6*N*D) ---------------
    def param_count(self, active_only: bool = False) -> int:
        """Closed-form parameter count (embedding included once)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d                                     # embedding
        if not self.tie_embeddings:
            total += v * d                                # lm head
        bias = 1 if self.use_bias else 0

        def attn_params() -> int:
            qp = d * self.q_dim + bias * self.q_dim
            kp = d * self.kv_dim + bias * self.kv_dim
            vp = d * self.kv_dim + bias * self.kv_dim
            op = self.q_dim * d + bias * d
            return qp + kp + vp + op

        def mlp_params(inner: int) -> int:
            if self.mlp == "swiglu":
                return 3 * d * inner + bias * (2 * inner + d)
            return 2 * d * inner + bias * (inner + d)

        def moe_params(active: bool) -> int:
            m = self.moe
            eff = m.expert_ff or ff
            router = d * m.num_experts
            shared = m.num_shared_experts * mlp_params(eff)
            routed = (m.top_k if active else m.num_experts) * mlp_params(eff)
            return router + shared + routed

        def ssm_params() -> int:
            s = self.ssm
            inner = s.expand * d
            nheads = inner // s.head_dim
            in_proj = d * (2 * inner + 2 * s.ngroups * s.state_dim + nheads)
            conv = s.conv_width * (inner + 2 * s.ngroups * s.state_dim)
            out_proj = inner * d
            extra = 3 * nheads + inner                     # A, D, dt_bias, gate norm
            return in_proj + conv + out_proj + extra

        for layer in range(self.num_layers):
            total += 2 * d                                 # two norms per block
            if self.is_attention_layer(layer):
                total += attn_params()
            else:
                total += ssm_params()
            if self.is_moe_layer(layer):
                total += moe_params(active_only)
            elif self.family == "ssm":
                pass                                       # mamba blocks have no MLP
            else:
                total += mlp_params(ff)
        if self.family == "encdec":
            # encoder layers: self-attn + mlp; decoder layers already counted above
            for _ in range(self.enc_layers):
                total += attn_params() + mlp_params(ff) + 2 * d
            # decoder cross-attention
            total += self.num_layers * (attn_params() + d)
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode
    # number of gradient-accumulation microbatches for the train kind (paper §4.2)
    microbatches: int = 1


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Everything the step builder needs besides the architecture itself."""

    arch: "ArchConfig"
    shape: "ShapeConfig"
    optimizer: str = "lamb"         # lamb | adamw | sgd
    learning_rate: float = 1e-3
    weight_decay: float = 0.01
    zero1: bool = True              # shard optimizer states over the data axis
    fuse_qkv: bool = True           # paper Fig 14/15 GEMM fusion
    fused_optimizer_kernel: bool = False   # route LAMB through the Pallas kernel
    # bf16 model params + fp32 master copies in the optimizer (paper §3.2.1 MP);
    # False = everything fp32 (the paper's FP32 baseline)
    master_weights: bool = True
    opt_state_dtype: str = "float32"       # bf16 = quantized m/v (beyond-paper)
    grad_clip: float = 1.0
    seed: int = 0
    # logical-axis overrides: tuple of (logical_name, mesh_axis|None)
    sharding_overrides: Tuple[Tuple[str, Optional[str]], ...] = ()

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
