"""internlm2-1.8b [dense] — arXiv:2403.17297.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
Small model: per the paper's Takeaway 11, the optimizer (LAMB) runtime share is
largest here among the dense archs — a useful characterization contrast.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2_048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8_192,
    vocab_size=92_544,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    pos_emb="rope",
    rope_theta=1_000_000.0,
    use_bias=False,
)
