"""Config registry: ``get_config("<arch-id>")`` and reduced smoke configs."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from .base import ArchConfig, MoEConfig, RunConfig, ShapeConfig, SSMConfig
from .shapes import (DECODE_32K, LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
                     cell_supported, input_specs)

from . import (bert_large, command_r_35b, deepseek_moe_16b, internlm2_1p8b,
               jamba_v0p1_52b, llama3p2_3b, llama4_maverick_400b,
               mamba2_1p3b, mistral_large_123b, qwen2_vl_2b, whisper_base)

_MODULES = (
    mistral_large_123b, command_r_35b, internlm2_1p8b, llama3p2_3b,
    deepseek_moe_16b, llama4_maverick_400b, whisper_base, mamba2_1p3b,
    jamba_v0p1_52b, qwen2_vl_2b, bert_large,
)

REGISTRY: Dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

# the 10 assigned archs (bert-large is the paper's own model, listed separately)
ASSIGNED: List[str] = [m.CONFIG.name for m in _MODULES[:-1]]


def get_config(name: str) -> ArchConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}") from None


def list_archs() -> List[str]:
    return list(REGISTRY)


def smoke_config(name: str) -> ArchConfig:
    """A reduced same-family config: tiny widths/layers, CPU-runnable in seconds."""
    full = get_config(name)
    kw = dict(
        name=full.name + "-smoke",
        num_layers=max(2, full.hybrid_period) if full.family == "hybrid" else 2,
        d_model=128,
        d_ff=0 if full.family == "ssm" else 256,
        vocab_size=512,
        head_dim=32,
        rope_theta=full.rope_theta,
        attn_chunk=64,
    )
    if full.num_heads:
        kw["num_heads"] = 4
        kw["num_kv_heads"] = min(4, max(1, full.num_kv_heads // 4)) or 1
    else:
        kw["num_heads"] = 0
        kw["num_kv_heads"] = 0
    if full.moe is not None:
        kw["moe"] = dataclasses.replace(
            full.moe, num_experts=4,
            top_k=min(2, full.moe.top_k),
            expert_ff=256 if full.moe.expert_ff else 0)
    if full.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            full.ssm, state_dim=16, head_dim=16, chunk=16)
    if full.family == "encdec":
        kw["enc_layers"] = 2
        kw["enc_seq_len"] = 16
    return dataclasses.replace(full, **kw)


__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "RunConfig", "ShapeConfig",
    "REGISTRY", "ASSIGNED", "SHAPES",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "get_config", "list_archs", "smoke_config", "cell_supported", "input_specs",
]
