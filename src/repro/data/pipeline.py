"""Deterministic synthetic data pipeline (MLM + causal-LM), host-sharded.

Real pre-training streams tokenized text; for a reproducible framework without
bundled corpora we generate structured synthetic token streams (Zipfian unigrams
with short-range Markov correlations so models have signal to learn) that are:

  * deterministic in (seed, step) — restart-safe: the pipeline state is just the
    step counter, checkpointed alongside the model;
  * host-sharded — each host materializes only its slice of the global batch
    (``host_id``/``num_hosts``), like a production loader on 1000+ nodes;
  * prefetchable — a background thread keeps ``prefetch`` batches ready.

Objectives:
  causal  : targets = inputs shifted left (decoder-only LMs)
  mlm     : BERT-style — 15% positions selected; 80% [MASK], 10% random, 10%
            kept; loss_mask marks selected positions (paper's Masked-LM task)
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np

MASK_TOKEN = 4
CLS_TOKEN = 2
SEP_TOKEN = 3


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    objective: str = "causal"      # causal | mlm
    seed: int = 1234
    host_id: int = 0
    num_hosts: int = 1
    mask_rate: float = 0.15
    zipf_a: float = 1.2
    markov_p: float = 0.35         # P(next token correlated with current)

    def __post_init__(self):
        # SeedSequence entropy (and default_rng in __init__) require this
        assert self.seed >= 0, "DataConfig.seed must be non-negative"


class SyntheticPipeline:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts
        rng = np.random.default_rng(cfg.seed)
        # fixed Zipf unigram table + a per-token "successor" table for structure
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / np.power(ranks, cfg.zipf_a)
        self._probs = probs / probs.sum()
        self._succ = rng.integers(5, cfg.vocab_size,
                                  size=cfg.vocab_size).astype(np.int32)

    # ------------------------------------------------------------------ core ---
    def _rng(self, step: int, domain: int) -> np.random.Generator:
        """Collision-free per-(seed, step, host) stream. Arithmetic mixes like
        ``seed*7 + step*13 + host_id`` alias across (step, host) pairs — e.g.
        (step=1, host=0) and (step=0, host=13) — handing different hosts (or
        adjacent steps) identical MLM masks. SeedSequence hashes the tuple
        coordinates independently; ``domain`` separates the token stream from
        the masking stream at the same coordinates."""
        cfg = self.cfg
        return np.random.default_rng(
            np.random.SeedSequence((cfg.seed, step, cfg.host_id, domain)))

    def _tokens_for(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = self._rng(step, 0)
        b, s = self.local_batch, cfg.seq_len
        base = rng.choice(cfg.vocab_size, size=(b, s), p=self._probs)
        corr = rng.random((b, s)) < cfg.markov_p
        toks = base.astype(np.int32)
        toks[:, 1:] = np.where(corr[:, 1:], self._succ[toks[:, :-1]],
                               toks[:, 1:])
        return np.clip(toks, 5, cfg.vocab_size - 1)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        toks = self._tokens_for(step)
        rng = self._rng(step, 1)
        if cfg.objective == "causal":
            inputs = toks
            targets = np.roll(toks, -1, axis=1)
            mask = np.ones_like(toks, np.float32)
            mask[:, -1] = 0.0
        elif cfg.objective == "mlm":
            inputs = toks.copy()
            targets = toks.copy()
            sel = rng.random(toks.shape) < cfg.mask_rate
            sel[:, 0] = False
            r = rng.random(toks.shape)
            inputs[sel & (r < 0.8)] = MASK_TOKEN
            rand_sel = sel & (r >= 0.8) & (r < 0.9)
            inputs[rand_sel] = rng.integers(
                5, cfg.vocab_size, size=int(rand_sel.sum()))
            mask = sel.astype(np.float32)
        else:
            raise ValueError(cfg.objective)
        return {"tokens": inputs.astype(np.int32),
                "targets": targets.astype(np.int32),
                "loss_mask": mask}

    # -------------------------------------------------------------- iterator ---
    def iterator(self, start_step: int = 0,
                 prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
        """Background-thread prefetching iterator, resumable at any step."""
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
