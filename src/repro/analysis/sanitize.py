"""Runtime sanitizer for the continuous serving engine.

The scheduler/allocator/prefix-index trio maintains a web of host-side
invariants (every physical page accounted for by exactly its holders, every
slot either running or free, the radix index's holds mirroring the
allocator) that ordinary tests only probe at the end of a trace — a
refcount leak or a slot desync mid-trace shows up, if at all, as a
corrupted stream thousands of tokens later. With ``sanitize=True`` (or
``REPRO_SANITIZE=1``) the engine calls :func:`check_engine` after **every
request completion**, so a violated invariant raises at the step that
broke it, naming the page/slot involved.

The checks are pure host-side reads (numpy + dicts — no device work, no
extra syncs), so sanitize mode costs O(pages + slots + index entries) per
completed request, not per token. The one device-side component — NaN/Inf
probes on logits at decode steps and chunk boundaries — lives in the
engine's jitted impls (an extra ``isfinite(...).all()`` output compiled in
only when sanitizing) and raises through :class:`SanitizerError` too.

Invariants (each has a seeded-violation test in ``tests/test_sanitize.py``):

1. **Allocator conservation** — the free list and the refcount map
   partition page ids 1..P-1: no page in both, none in neither (a page in
   neither is a *leak*: unreachable until restart), no duplicate free-list
   entries, no refcount below 1, the null page never tracked.
2. **Refcount accounting** — each live page's refcount equals its visible
   holders: occurrences across running slots' page-table rows, plus the
   prefix index's holds, plus pending copy-on-write source pins.
3. **Slot/mask consistency** — running slots and the free-slot list
   partition ``range(num_slots)``; a free slot's page-table row is all
   null with ``seq_len`` 0; a running row is a null-free prefix with
   enough pages for its ``seq_len``, and the ``seq_len`` itself matches
   the sequence's lifecycle (``prefill_target`` mid-prefill,
   ``len(context) - 1`` once decoding — the last generated token's K/V is
   not yet written).
4. **PrefixIndex agreement** — the incrementally maintained ``_holds`` map
   equals a from-scratch recount of the index's entries, every interior
   node's child count matches its actual children, and every held page is
   live in the allocator with at least that many refs.

``SanitizerError`` subclasses ``AssertionError``: a violation is a broken
internal invariant, not a user error.
"""
from __future__ import annotations

import os
from collections import Counter
from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:                       # pragma: no cover - typing only
    from ..serving.engine import ContinuousEngine
    from ..serving.kv_cache import PageAllocator
    from ..serving.scheduler import PrefixIndex

NULL_PAGE = 0


class SanitizerError(AssertionError):
    """A serving-engine invariant does not hold."""


def sanitize_enabled() -> bool:
    """Environment opt-in: ``REPRO_SANITIZE`` set to anything but ''/'0'."""
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


def _fail(invariant: str, detail: str) -> None:
    raise SanitizerError(f"[sanitize:{invariant}] {detail}")


# ----------------------------------------------------------- 1. conservation --

def check_allocator(allocator: "PageAllocator") -> None:
    """Free list ∪ refcounted pages partition {1..P-1}; nothing leaks."""
    ids = set(range(1, allocator.num_pages))
    free = allocator._free
    refs = allocator._refs
    if len(free) != len(set(free)):
        dupes = [p for p, n in Counter(free).items() if n > 1]
        _fail("conservation", f"duplicate free-list entries: {dupes}")
    both = set(free) & set(refs)
    if both:
        _fail("conservation", f"pages both free and refcounted: "
                              f"{sorted(both)}")
    leaked = ids - set(free) - set(refs)
    if leaked:
        _fail("conservation", f"leaked pages (neither free nor refcounted, "
                              f"unreachable until restart): {sorted(leaked)}")
    unknown = (set(free) | set(refs)) - ids
    if unknown:
        _fail("conservation", f"tracked ids outside 1..{allocator.num_pages - 1}"
                              f": {sorted(unknown)} (null page is reserved)")
    bad = {p: n for p, n in refs.items() if n < 1}
    if bad:
        _fail("conservation", f"refcount below 1: {bad}")


# ------------------------------------------------------------- 2. refcounts --

def check_refcounts(engine: "ContinuousEngine") -> None:
    """Every page's refcount == page-table occurrences + index holds + CoW
    source pins — nothing holds a page invisibly, nothing forgot a hold."""
    sched = engine.scheduler
    expected: Counter = Counter()
    for slot, seq in sched.running.items():
        row = sched.cache.page_table[slot]
        for p in row[row != NULL_PAGE]:
            expected[int(p)] += 1
        if seq.cow is not None:
            expected[seq.cow[0]] += 1   # pinned until the engine copies it
    if sched.prefix is not None:
        for p, n in sched.prefix._holds.items():
            expected[p] += n
    refs = sched.allocator._refs
    for p, n in expected.items():
        have = refs.get(p, 0)
        if have != n:
            _fail("refcount", f"page {p}: allocator holds {have} ref(s) but "
                              f"{n} visible holder(s) (page tables + prefix "
                              "holds + CoW pins)")
    orphans = {p: n for p, n in refs.items() if p not in expected}
    if orphans:
        _fail("refcount", f"refcounted pages with no visible holder "
                          f"(leak): {orphans}")


# ----------------------------------------------------------- 3. slots/masks --

def check_slots(engine: "ContinuousEngine") -> None:
    """Running ∪ free slots partition range(num_slots); rows and seq_lens
    agree with each sequence's lifecycle stage."""
    sched = engine.scheduler
    n_slots = engine.num_slots
    running = set(sched.running)
    free = sched._free_slots
    if len(free) != len(set(free)):
        _fail("slots", f"duplicate free-slot entries: "
                       f"{[s for s, n in Counter(free).items() if n > 1]}")
    both = running & set(free)
    if both:
        _fail("slots", f"slots both running and free: {sorted(both)}")
    lost = set(range(n_slots)) - running - set(free)
    if lost:
        _fail("slots", f"slots neither running nor free: {sorted(lost)}")
    for s in free:
        if sched.cache.page_table[s].any():
            _fail("slots", f"free slot {s} still owns pages "
                           f"{[int(p) for p in sched.cache.page_table[s] if p]}")
        if sched.cache.seq_lens[s] != 0:
            _fail("slots", f"free slot {s} has seq_len "
                           f"{int(sched.cache.seq_lens[s])} != 0")
    for s, seq in sched.running.items():
        row = sched.cache.page_table[s]
        n_pages = int((row != NULL_PAGE).sum())
        if row[:n_pages].min(initial=1) == NULL_PAGE or \
                row[n_pages:].any():
            _fail("slots", f"running slot {s} page row is not a null-free "
                           f"prefix: {row.tolist()}")
        seq_len = int(sched.cache.seq_lens[s])
        if n_pages * engine.page_size < seq_len:
            _fail("slots", f"running slot {s}: {n_pages} page(s) cover "
                           f"{n_pages * engine.page_size} tokens < seq_len "
                           f"{seq_len}")
        if seq.prefilled < seq.prefill_target:
            want = seq.prefill_target
            stage = "mid-prefill"
        else:
            # the newest generated token's K/V is never in the pages yet
            want = len(seq.request.prompt) + len(seq.generated) - 1
            stage = "decoding"
        if seq_len != want:
            _fail("slots", f"running slot {s} ({stage}): seq_len {seq_len} "
                           f"!= expected {want} (prompt "
                           f"{len(seq.request.prompt)}, generated "
                           f"{len(seq.generated)}, prefill_target "
                           f"{seq.prefill_target})")


# ---------------------------------------------------------- 4. prefix index --

def check_prefix(prefix: "PrefixIndex", allocator: "PageAllocator") -> None:
    """The incrementally maintained holds map and children counts equal a
    from-scratch recount; every held page is live in the allocator."""
    recount: Counter = Counter()
    entries = list(prefix._full.values())
    for bucket in prefix._partials.values():
        entries.extend(bucket.values())
    for e in entries:
        recount[e.page] += 1
    if dict(recount) != prefix._holds:
        drift = {p: (prefix._holds.get(p, 0), recount.get(p, 0))
                 for p in set(prefix._holds) | set(recount)
                 if prefix._holds.get(p, 0) != recount.get(p, 0)}
        _fail("prefix", f"holds map drifted from entries (page: "
                        f"(incremental, recount)): {drift}")
    children: Counter = Counter()
    for e in entries:
        if e.parent_key is not None:
            children[e.parent_key] += 1
    for key, e in prefix._full.items():
        if e.children != children.get(key, 0):
            _fail("prefix", f"entry {key!r} claims {e.children} children, "
                            f"recount says {children.get(key, 0)}")
    for p, n in prefix._holds.items():
        if allocator.ref_count(p) < n:
            _fail("prefix", f"index holds page {p} x{n} but allocator has "
                            f"only {allocator.ref_count(p)} ref(s)")


# ------------------------------------------------------------------- driver --

def check_engine(engine: "ContinuousEngine") -> None:
    """All host-side invariants, in dependency order (conservation first so
    later diagnostics can trust the allocator's own books)."""
    sched = engine.scheduler
    check_allocator(sched.allocator)
    check_refcounts(engine)
    check_slots(engine)
    if sched.prefix is not None:
        check_prefix(sched.prefix, sched.allocator)


def check_finite_probe(probe, where: str) -> None:
    """Raise on a failed device-side NaN/Inf probe (an ``isfinite().all()``
    scalar the sanitizing engine compiles into its steps)."""
    if not bool(np.asarray(probe)):
        _fail("finite", f"non-finite logits/activations detected at {where} "
                        "— NaN/Inf upstream of sampling")
