"""Static recompilation auditor for the continuous serving engine.

The engine's jit cache is *lazy per variant* (decode/prefill × sampled ×
filtered × fused × final): each variant compiles once, on the first traffic that
needs it, and the whole serving design rests on the cache then being
**closed** — fixed batch shapes, fixed chunk shapes, static flags — so
steps 2..N of any trace add zero new traces. That closure is also exactly
what the lazy cache can silently mask: a shape or weak-type leak into a
traced signature (a python int where an array belonged, a page table that
changed width) retraces *the same variant* every step, which perf tests
read as "mysteriously slow" rather than "broken".

This auditor proves closure statically. :class:`AuditEngine` replaces the
engine's ``_build`` step compiler with a recorder that **abstract-evals**
(``jax.eval_shape`` — no device execution, no kernels, no FLOPs) each call
and logs its abstract signature under the variant's jit-cache key. Running
a representative mixed trace (greedy + sampled + filtered traffic, shared
prefixes, a starved page pool forcing growth and preemption replay) then
asserts every exercised variant saw exactly ONE signature. A planted
retrace — e.g. mutating the chunk size mid-trace — fails loudly
(``tests/test_recompile_audit.py`` seeds exactly that).

Coverage matrix (``python -m repro.analysis.recompile`` runs all of it; the
tests pin representative cells). Every row runs once per fused-decode
setting (``fd`` ∈ {True, False} — both halves of the bit-parity contract
must keep a closed cache):

    every servable family   × tp ∈ {1, ..devices}  × fused sampler × N=1
    dense                   × tp ∈ {1, ..devices}  × ref sampler   × N=1
    every servable family   × tp=1                 × fused sampler × N=4
    dense                   × tp ∈ {2, ..devices}  × fused sampler × N=4

The N=4 rows audit the multi-step compiled decode loop: its decode keys
gain the horizon element (``("decode", sampled, filtered, fused, fd, N)``)
and the per-dispatch predicate arrays (active mask, budgets, page capacity,
EOS ids) must not perturb the traced signature. tp > 1 audits shard-map
the abstract step over a real device mesh, so they need
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the tests run them
in a subprocess; the CLI audits every tp the visible device count
supports).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import smoke_config
from ..models import build_model
from ..parallel import sharding as shardlib
from ..serving.engine import SERVABLE_FAMILIES, ContinuousEngine
from ..serving.sampling import SamplingParams
from ..serving.scheduler import Request

# the smoke-sized representative of each servable family
FAMILY_ARCHS: Dict[str, str] = {
    "dense": "llama3.2-3b",
    "moe": "deepseek-moe-16b",
    "vlm": "qwen2-vl-2b",
    "ssm": "mamba2-1.3b",
    "hybrid": "jamba-v0.1-52b",
}
assert set(FAMILY_ARCHS) == set(SERVABLE_FAMILIES)


class AuditError(AssertionError):
    """The jit cache is not closed: a variant traced more than once."""


def _abstract(leaf) -> Tuple:
    """The part of a leaf that decides whether jit re-traces: shape, dtype,
    weak-typedness. A python scalar slipping in where an array belonged
    shows up here as a distinct (weak) signature."""
    if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
        return (tuple(leaf.shape), str(leaf.dtype),
                bool(getattr(leaf, "weak_type", False)))
    return ("pyval", type(leaf).__name__, repr(leaf))


class _Recorder:
    """Stands in for one compiled step variant: abstract-evals on each new
    signature, replays cached zero outputs otherwise. The zero token stream
    keeps the host scheduler honest (stop checks, slot recycling, page
    growth all run for real); only the model math is skipped."""

    def __init__(self, engine: "AuditEngine", impl, key: Tuple):
        self.engine = engine
        self.impl = impl
        self.key = key
        self._outs: Dict[Tuple, Any] = {}

    def __call__(self, *args):
        leaves = jax.tree_util.tree_leaves(args)
        sig = tuple(_abstract(leaf) for leaf in leaves)
        sigs = self.engine.signatures.setdefault(self.key, [])
        if sig not in sigs:
            sigs.append(sig)
            out_shapes = jax.eval_shape(self.impl, *args)
            self._outs[sig] = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), out_shapes)
        return self._outs[sig]


class AuditEngine(ContinuousEngine):
    """A ContinuousEngine whose steps never execute: ``_build`` hands back a
    signature recorder instead of a jit-compiled function. Everything else —
    scheduler, allocator, prefix index, chunking, preemption — runs the real
    host code against the zero token stream."""

    def __init__(self, model, params, **kw):
        # the sanitizer's device-side probes would read the recorder's
        # all-zeros output as "non-finite check failed: False" — the audit
        # is abstract by construction, so force it off
        kw["sanitize"] = False
        super().__init__(model, params, **kw)
        # jit-cache key -> ordered distinct abstract signatures
        self.signatures: Dict[Tuple, List[Tuple]] = {}

    def _build(self, impl, in_specs, out_specs, donate, key=()):
        if self.mesh is not None:
            impl = shardlib.shard_map_tp(impl, self.mesh, in_specs, out_specs)
        return _Recorder(self, impl, key)


@dataclasses.dataclass
class AuditReport:
    """Signature census of one audited trace: family, tp, and per-variant
    distinct-signature counts."""
    family: str
    arch: str
    tp: int
    signatures: Dict[Tuple, List[Tuple]]

    @property
    def variants(self) -> List[Tuple]:
        return sorted(self.signatures)

    def check(self) -> "AuditReport":
        """Raise AuditError unless every variant has exactly one trace."""
        if not self.signatures:
            raise AuditError(
                f"[{self.family}/tp={self.tp}] trace exercised no engine "
                "step at all — the audit traffic is broken")
        open_keys = {k: len(v) for k, v in self.signatures.items()
                     if len(v) != 1}
        if open_keys:
            detail = "; ".join(
                f"{k}: {n} distinct signatures" for k, n in
                sorted(open_keys.items(), key=lambda kv: str(kv[0])))
            raise AuditError(
                f"[{self.family}/tp={self.tp}] jit cache not closed — a "
                f"variant re-traced after its first call: {detail}")
        return self

    def summary(self) -> str:
        keys = ", ".join(str(k) for k in self.variants)
        return (f"{self.family:<7} ({self.arch}) tp={self.tp}: "
                f"{len(self.signatures)} variant(s), 1 trace each [{keys}]")


def _audit_requests(vocab: int, seed: int = 0) -> List[Request]:
    """Mixed traffic that exercises every step variant the engine can lazily
    build: greedy, sampled-unfiltered, sampled-filtered; a shared prefix
    (prefix cache + CoW tail where supported); prompt lengths spanning
    multiple chunks; generation lengths that outgrow pages."""
    rng = np.random.default_rng(seed)
    shared = list(map(int, rng.integers(5, vocab, 10)))
    reqs = []
    for i in range(7):
        if i < 3:
            # shared 10-token prefix: 2 full pages + a partial tail, so the
            # second/third admissions exercise prefix sharing and CoW
            prompt = shared + list(map(int, rng.integers(
                5, vocab, int(rng.integers(2, 6)))))
        elif i == 3:
            # longer than one prefill chunk: non-final chunk variant
            prompt = list(map(int, rng.integers(5, vocab, 22)))
        else:
            prompt = list(map(int, rng.integers(
                5, vocab, int(rng.integers(4, 14)))))
        sp = (SamplingParams(),                                   # greedy
              SamplingParams(temperature=0.8, seed=10 + i),       # sampled
              SamplingParams(temperature=0.9, top_k=8, top_p=0.9,
                             seed=20 + i))[i % 3]                 # filtered
        reqs.append(Request(uid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(4, 10)),
                            sampling=sp))
    return reqs


def audit_family(family: str, *, tp: int = 1, fused_sampling: bool = True,
                 decode_steps: int = 1, fused_decode: Optional[bool] = None,
                 requests: Optional[Sequence[Request]] = None) -> AuditReport:
    """Abstract-serve one family's smoke arch and assert cache closure.

    The pool is deliberately starved (2 slots, 12 pages) so the trace also
    covers page growth, prefix eviction, CoW tail copies, and forced-replay
    preemption — the paths where a retrace bug would hide behind rare
    traffic. ``fused_sampling=False`` audits the sort-based reference
    filter's variants (same key arity, ``fused`` element pinned False).
    ``decode_steps > 1`` audits the multi-step compiled decode loop's
    variants instead (decode keys gain the horizon element; the per-dispatch
    predicate arrays must not perturb the traced signature).
    ``fused_decode`` pins the fused-decode flag (None = the engine's
    default resolution), auditing the fused residual-stream + streaming-head
    step variants — same key arity, ``fd`` element pinned."""
    arch_name = FAMILY_ARCHS[family]
    arch = smoke_config(arch_name)
    if tp > 1 and arch.num_kv_heads % tp and tp % arch.num_kv_heads:
        arch = dataclasses.replace(arch, num_kv_heads=tp)
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    engine = AuditEngine(model, params, num_slots=2, num_pages=12,
                         page_size=4, max_seq_len=40, tp=tp,
                         fused_sampling=fused_sampling,
                         decode_steps=decode_steps,
                         fused_decode=fused_decode)
    reqs = list(requests) if requests is not None \
        else _audit_requests(arch.vocab_size)
    results = engine.run(reqs)
    assert all("tokens" in r for r in results.values())
    return AuditReport(family=family, arch=arch_name, tp=tp,
                       signatures=dict(engine.signatures)).check()


def audit_all(tps: Sequence[int] = (1,),
              families: Sequence[str] = SERVABLE_FAMILIES
              ) -> List[AuditReport]:
    return [audit_family(f, tp=tp) for tp in tps for f in families]


def main() -> int:
    tps = [1]
    if jax.device_count() >= 2:
        tps.append(2)
    print(f"[recompile-audit] families={list(SERVABLE_FAMILIES)} tps={tps}")
    failed = 0
    # dense also audits the sort-based reference filter (fused off) so BOTH
    # filtered-variant implementations prove closure, not just the default;
    # every family re-audits at decode_steps=4 so the multi-step compiled
    # decode loop's horizon-keyed variants prove closure too (dense also at
    # every tp the mesh supports). Every (family, tp) cell audits BOTH
    # fused-decode settings: the fused residual-stream + streaming-head
    # variants and the reference variants are separate jit keys (the ``fd``
    # element) and each must keep a closed cache.
    jobs = [(f, tp, True, 1, fd) for tp in tps for f in SERVABLE_FAMILIES
            for fd in (True, False)]
    jobs += [("dense", tp, False, 1, None) for tp in tps]
    jobs += [(f, 1, True, 4, fd) for f in SERVABLE_FAMILIES
             for fd in (True, False)]
    jobs += [("dense", tp, True, 4, None) for tp in tps if tp > 1]
    for family, tp, fused, steps, fd in jobs:
        try:
            report = audit_family(family, tp=tp, fused_sampling=fused,
                                  decode_steps=steps, fused_decode=fd)
        except AuditError as e:
            failed += 1
            print(f"FAIL {e}")
        else:
            tag = "" if fused else " [sampler=ref]"
            tag += f" [decode_steps={steps}]" if steps > 1 else ""
            tag += "" if fd is None else f" [fused_decode={fd}]"
            print(f"ok   {report.summary()}{tag}")
    if failed:
        print(f"[recompile-audit] {failed} audit(s) FAILED — the jit cache "
              "is not closed; see signatures above")
        return 1
    print("[recompile-audit] all caches closed (steps 2..N add zero traces)")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
