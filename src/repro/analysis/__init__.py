"""repro.analysis — correctness tooling for the serving stack.

Three instruments, one package:

- ``lint``      static AST pass (jaxlint): host syncs in jitted/hot paths,
                tracer branching, PRNG key reuse, Pallas grid/masking/dtype
                rules. Stdlib-only — ``tools/jaxlint.py`` loads it by file
                path so CI lints without a jax install.
- ``sanitize``  opt-in runtime invariant checks for ``ContinuousEngine``
                (``sanitize=True`` / ``REPRO_SANITIZE=1``): page-refcount
                conservation + leak freedom, slot/active-mask consistency,
                PrefixIndex holds-map agreement, NaN/Inf probes on logits
                at chunk boundaries.
- ``recompile`` static recompilation auditor: abstract-evals every servable
                family x engine variant x tp with ``jax.eval_shape`` (no
                device execution) and asserts the jit cache signature set is
                closed — steps 2..N add zero new traces.

Imports are lazy so ``lint`` stays importable (and fast) in contexts with
no jax — the attribute you touch decides what loads.
"""
import importlib

_SUBMODULES = ("lint", "sanitize", "recompile")
_LAZY = {
    "RULES": "lint", "Finding": "lint",
    "lint_source": "lint", "lint_paths": "lint",
    "SanitizerError": "sanitize", "check_engine": "sanitize",
    "sanitize_enabled": "sanitize",
    "AuditError": "recompile", "AuditReport": "recompile",
    "audit_family": "recompile", "audit_all": "recompile",
}

__all__ = list(_SUBMODULES) + list(_LAZY)


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f".{name}", __name__)
    if name in _LAZY:
        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
