"""jaxlint: a JAX/Pallas-aware static-analysis pass (AST-based, stdlib-only).

The source paper's finding — once GEMMs are tuned, BERT-class inference is
dominated by memory-intensive and *host-side* overheads — makes a class of
silent defect expensive in exactly this repo: a stray ``.item()`` in a decode
loop serializes async dispatch, a Python branch on a tracer retraces per
value, a reused PRNG key correlates "independent" draws, and a Pallas grid
built with plain ``//`` drops the partial tail block. None of these fail a
unit test; all of them show up as tok/s or as silently wrong numerics. This
module catches them at review time, before they land.

It is deliberately **stdlib-only** (``ast`` + ``re``): ``tools/jaxlint.py``
loads it by file path, so the CI lint job needs no jax install and runs in
seconds.

Rule catalog
------------
``jit-host-sync``        Host-side ops inside a jit-traced function:
                         ``.item()`` / ``.tolist()`` / ``.block_until_ready()``
                         / ``jax.device_get``, ``float()/int()/bool()`` on a
                         traced value, and ``np.*`` calls on traced arguments
                         (numpy pulls the value to the host mid-trace).
``hot-host-sync``        Device syncs inside a *host* hot loop (a loop that
                         calls a compiled step): ``.item()`` /
                         ``.block_until_ready()`` / ``jax.block_until_ready``
                         on any value, and ``float()/int()/np.asarray()`` on
                         values returned by compiled calls. Syncing once
                         after the loop is the fix pattern (and is not
                         flagged).
``tracer-branch``        Python ``if``/``while``/``for range()`` control flow
                         on a traced value inside a jit-traced function —
                         either a bug (ConcretizationTypeError) or a silent
                         per-value retrace. Mark the arg static or use
                         ``lax.cond``/``jnp.where``. Keyword-only params are
                         assumed static (this repo's jit-variant idiom), as
                         are ``x.shape``/``x.ndim``/``x.dtype`` and
                         comparisons against string constants.
``prng-key-reuse``       The same PRNG key Name consumed by two
                         ``jax.random.*`` calls without an intervening
                         rebind, or consumed inside a loop that never
                         rebinds it — the draws are identical/correlated,
                         not independent. ``split``/``fold_in`` first.
``nonhashable-static``   A list/dict/set literal passed for a parameter the
                         function declares static (``static_argnames`` /
                         ``static_argnums``) — jit static args must be
                         hashable; this raises at call time.
``fstring-sync``         An f-string interpolating a traced value (in a jit
                         function) or a compiled-call result (in a host hot
                         loop) — formatting forces a device sync / embeds a
                         tracer repr into logs.
``pallas-grid-floordiv`` A ``pallas_call`` grid dimension computed with plain
                         ``//``: when the axis is not a block multiple the
                         remainder is silently never visited. Use
                         ``pl.cdiv`` (+ in-kernel masking) or assert
                         divisibility.
``pallas-accum-dtype``   A dot (``jnp.dot`` / ``lax.dot`` / ``dot_general`` /
                         ``pl.dot`` / ``@``) inside a Pallas kernel with
                         neither ``preferred_element_type=`` nor an operand
                         visibly cast to float32 — bf16 inputs would
                         accumulate in bf16 (the mixed-precision rule:
                         accumulate matmuls in fp32).
``pallas-partial-mask``  A ``pallas_call`` whose grid uses ``cdiv`` (so the
                         last block is partial) but whose kernel shows no
                         masking construct (``pl.when``, ``jnp.where``, a
                         ``mask=`` kwarg, or an iota/program_id bound check)
                         — the tail block reads/writes out-of-range rows.

Jit-context detection is syntactic and documented: a function is analyzed as
jit-traced when it (a) is decorated with ``jax.jit`` (bare or via
``functools.partial``), (b) is passed by name to ``jax.jit(...)`` anywhere
in the module, (c) is a *method* named ``_*_impl`` (the engine's lazily
jitted step idiom), or (d) is a Pallas kernel (passed — possibly through one
``functools.partial`` — to ``pallas_call``).

Suppression
-----------
A finding is suppressed by an annotation on its line or the line above::

    x = np.asarray(tok)  # jaxlint: allow[hot-host-sync] the one designed
                         # host sync per step: the scheduler needs the token

The bracket lists one or more rule ids (comma-separated); everything after
the bracket is the REQUIRED one-line justification. A bare annotation
(``allow-missing-reason``) or an unknown rule id (``allow-unknown-rule``)
is itself reported, so the allowlist stays auditable.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES: Dict[str, str] = {
    "jit-host-sync": "host-side op on a traced value inside a jit function",
    "hot-host-sync": "device sync inside a host hot loop",
    "tracer-branch": "Python control flow on a traced value",
    "prng-key-reuse": "PRNG key consumed twice without split/fold_in",
    "nonhashable-static": "unhashable literal passed for a static jit arg",
    "fstring-sync": "f-string interpolating a traced/device value",
    "pallas-grid-floordiv": "pallas grid built with plain // (drops the "
                            "partial tail block)",
    "pallas-accum-dtype": "kernel dot without fp32 accumulation",
    "pallas-partial-mask": "cdiv grid but no masking in the kernel",
    "allow-unknown-rule": "jaxlint allow[] names a rule that does not exist",
    "allow-missing-reason": "jaxlint allow[] without a justification",
}

# array attributes that are static under tracing (reading them never syncs)
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "weak_type", "sharding",
                "itemsize", "aval"}

# jax.random functions that CONSUME a key (first positional arg)
_KEY_CONSUMERS = {
    "split", "fold_in", "normal", "uniform", "categorical", "bernoulli",
    "gumbel", "randint", "truncated_normal", "permutation", "choice",
    "bits", "exponential", "poisson", "gamma", "beta", "laplace", "cauchy",
    "dirichlet", "loggamma", "rademacher", "t", "orthogonal", "ball",
}

_SYNC_METHODS = {"item", "tolist", "block_until_ready", "copy_to_host_async"}

_ALLOW_RE = re.compile(r"#\s*jaxlint:\s*allow\[([^\]]*)\]\s*[-—:]?\s*(.*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] " \
               f"{self.message}"


# --------------------------------------------------------------- AST helpers --

def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.random.normal' for a Name/Attribute chain, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _is_jit_ref(node: ast.AST) -> bool:
    d = _dotted(node)
    return d in ("jax.jit", "jit")


def _is_partial_ref(node: ast.AST) -> bool:
    d = _dotted(node)
    return d in ("functools.partial", "partial")


def _str_elements(node: ast.AST) -> Tuple[str, ...]:
    """Constant strings of a tuple/list literal (for static_argnames)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, str))
    return ()


def _int_elements(node: ast.AST) -> Tuple[int, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, int))
    return ()


def _assigned_names(target: ast.AST) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in target.elts:
            out.extend(_assigned_names(e))
        return out
    return []


def _contains_call_to(tree: ast.AST, names: Set[str]) -> bool:
    """True if the subtree calls any bare name in ``names`` or contains a
    double call ``f(...)(...)`` (the lazily-built compiled-step idiom)."""
    for n in ast.walk(tree):
        if isinstance(n, ast.Call):
            if isinstance(n.func, ast.Call):
                return True
            if isinstance(n.func, ast.Name) and n.func.id in names:
                return True
    return False


class _TracedUses(ast.NodeVisitor):
    """Collect bare uses of traced names inside an expression, skipping
    static contexts (shape/dtype attrs, len()/isinstance(), comparisons
    against string constants)."""

    def __init__(self, traced: Set[str]):
        self.traced = traced
        self.uses: List[ast.Name] = []

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if node.attr in STATIC_ATTRS:
            return                      # x.shape — static under tracing
        self.visit(node.value)

    def visit_Call(self, node: ast.Call) -> None:
        fn = _dotted(node.func)
        if fn in ("len", "isinstance", "getattr", "hasattr", "type", "range"):
            # len(x)/x.shape-style static introspection; range() handled by
            # the caller for `for` loops (range over a traced bound is the
            # finding itself, so the For visitor inspects args directly)
            if fn == "range":
                for a in node.args:
                    self.visit(a)
            return
        for a in node.args:
            self.visit(a)
        for k in node.keywords:
            self.visit(k.value)

    def visit_Compare(self, node: ast.Compare) -> None:
        if any(isinstance(c, ast.Constant) and isinstance(c.value, str)
               for c in node.comparators):
            return                      # `mixer == "attn"` — static dispatch
        self.visit(node.left)
        for c in node.comparators:
            self.visit(c)

    def visit_Name(self, node: ast.Name) -> None:
        if node.id in self.traced:
            self.uses.append(node)


def _traced_uses(expr: ast.AST, traced: Set[str]) -> List[ast.Name]:
    v = _TracedUses(traced)
    v.visit(expr)
    return v.uses


def _expr_names(expr: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(expr) if isinstance(n, ast.Name)}


# ------------------------------------------------------------- module index --

@dataclasses.dataclass
class _JitInfo:
    node: ast.AST                       # FunctionDef
    how: str                            # "decorator" | "jit-call" | "_impl"


class _ModuleIndex:
    """One pass over the module: which functions are jit-traced, which are
    Pallas kernels, which names alias jitted functions (and their static
    params), and where the pallas_call sites are."""

    def __init__(self, tree: ast.Module):
        self.functions: Dict[str, ast.AST] = {}
        self.jit_functions: Dict[str, _JitInfo] = {}
        self.kernel_functions: Dict[str, ast.AST] = {}
        self.pallas_sites: List[ast.Call] = []
        # callable name -> static parameter names (for nonhashable-static)
        self.static_params: Dict[str, Set[str]] = {}
        # name -> kernel fn name (functools.partial(kern, ...) assignments)
        partial_of: Dict[str, str] = {}

        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions[node.name] = node

        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_def(node)
            elif isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Call):
                call = node.value
                names = []
                for t in node.targets:
                    names.extend(_assigned_names(t))
                if _is_partial_ref(call.func) and call.args and isinstance(
                        call.args[0], ast.Name):
                    for nm in names:
                        partial_of[nm] = call.args[0].id
                if _is_jit_ref(call.func):
                    statics = self._jit_static_names(call)
                    for nm in names:
                        if statics:
                            self.static_params[nm] = statics
            if isinstance(node, ast.Call) and _is_jit_ref(node.func) \
                    and node.args:
                tgt = node.args[0]
                if isinstance(tgt, ast.Name) and tgt.id in self.functions:
                    self.jit_functions.setdefault(
                        tgt.id, _JitInfo(self.functions[tgt.id], "jit-call"))
                    statics = self._jit_static_names(
                        node, self.functions.get(tgt.id)
                        if isinstance(tgt, ast.Name) else None)
                    if statics and isinstance(tgt, ast.Name):
                        self.static_params.setdefault(tgt.id, set()).update(
                            statics)
            if isinstance(node, ast.Call):
                d = _dotted(node.func)
                if d and d.split(".")[-1] == "pallas_call" and node.args:
                    self.pallas_sites.append(node)
                    kern = node.args[0]
                    kname = None
                    if isinstance(kern, ast.Name):
                        kname = partial_of.get(kern.id, kern.id)
                    elif isinstance(kern, ast.Call) and _is_partial_ref(
                            kern.func) and kern.args and isinstance(
                            kern.args[0], ast.Name):
                        kname = kern.args[0].id
                    if kname and kname in self.functions:
                        self.kernel_functions[kname] = self.functions[kname]

    def _jit_static_names(self, call: ast.Call,
                          fn: Optional[ast.AST] = None) -> Set[str]:
        """static_argnames strings (+ static_argnums resolved through the
        def when available)."""
        out: Set[str] = set()
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                out.update(_str_elements(kw.value))
            elif kw.arg == "static_argnums" and fn is not None:
                params = [a.arg for a in fn.args.args]
                for i in _int_elements(kw.value):
                    if 0 <= i < len(params):
                        out.add(params[i])
        return out

    def _scan_def(self, node) -> None:
        for dec in node.decorator_list:
            if _is_jit_ref(dec):
                self.jit_functions[node.name] = _JitInfo(node, "decorator")
            elif isinstance(dec, ast.Call):
                if _is_jit_ref(dec.func):
                    self.jit_functions[node.name] = _JitInfo(node, "decorator")
                    statics = self._jit_static_names(dec, node)
                    if statics:
                        self.static_params[node.name] = statics
                elif _is_partial_ref(dec.func) and dec.args and _is_jit_ref(
                        dec.args[0]):
                    self.jit_functions[node.name] = _JitInfo(node, "decorator")
                    statics = self._jit_static_names(dec, node)
                    if statics:
                        self.static_params[node.name] = statics
        # the engine idiom: methods named _*_impl are jitted lazily by a
        # builder the AST cannot follow; treat them as jit-traced
        args = node.args.args
        if node.name.endswith("_impl") and args and args[0].arg == "self":
            self.jit_functions.setdefault(
                node.name, _JitInfo(node, "_impl"))


# ------------------------------------------------------------ the lint pass --

class _Linter:
    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.findings: List[Finding] = []
        self.allows: Dict[int, Tuple[Set[str], str]] = {}

    def report(self, node: ast.AST, rule: str, message: str) -> None:
        line = getattr(node, "lineno", 0)
        col = getattr(node, "col_offset", 0)
        if self._allowed(line, rule):
            return
        self.findings.append(Finding(self.path, line, col, rule, message))

    def _allowed(self, line: int, rule: str) -> bool:
        """An allow[] on the finding's line, or anywhere in the contiguous
        comment block immediately above it (multi-line justifications)."""
        lines = self.source.splitlines()

        def hit(ln: int) -> bool:
            entry = self.allows.get(ln)
            return bool(entry) and (rule in entry[0] or "*" in entry[0])

        if hit(line):
            return True
        ln = line - 1
        while ln >= 1 and ln <= len(lines) \
                and lines[ln - 1].lstrip().startswith("#"):
            if hit(ln):
                return True
            ln -= 1
        return False

    # ---------------------------------------------------------- annotations --
    def _parse_allows(self) -> None:
        for i, text in enumerate(self.source.splitlines(), start=1):
            m = _ALLOW_RE.search(text)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            reason = m.group(2).strip()
            self.allows[i] = (rules, reason)
            for r in rules - set(RULES) - {"*"}:
                self.findings.append(Finding(
                    self.path, i, 0, "allow-unknown-rule",
                    f"allow[] names unknown rule {r!r} (see --list-rules)"))
            if not reason:
                self.findings.append(Finding(
                    self.path, i, 0, "allow-missing-reason",
                    "allow[] needs a one-line justification after the "
                    "bracket"))

    # ----------------------------------------------------------------- run --
    def run(self) -> List[Finding]:
        self._parse_allows()
        try:
            tree = ast.parse(self.source, filename=self.path)
        except SyntaxError as e:
            self.findings.append(Finding(
                self.path, e.lineno or 0, e.offset or 0, "jit-host-sync",
                f"file does not parse: {e.msg}"))
            return self.findings
        index = _ModuleIndex(tree)

        analyzed_jit = {id(i.node) for i in index.jit_functions.values()}
        analyzed_jit |= {id(f) for f in index.kernel_functions.values()}
        for name, info in index.jit_functions.items():
            self._check_jit_function(info.node)
        for name, fn in index.kernel_functions.items():
            self._check_kernel(fn)
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and id(node) not in analyzed_jit:
                self._check_host_function(node, index)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                pass
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_key_reuse(node)
        self._check_static_call_sites(tree, index)
        for site in index.pallas_sites:
            self._check_pallas_site(site, index)
        return self.findings

    # ---------------------------------------------------- jit-traced bodies --
    def _traced_names(self, fn) -> Set[str]:
        """Positional params (minus self) + names derived from them by
        assignment, one forward pass in source order."""
        traced: Set[str] = set()
        params = fn.args.posonlyargs + fn.args.args
        for a in params:
            if a.arg != "self":
                traced.add(a.arg)
        if fn.args.vararg:
            traced.add(fn.args.vararg.arg)
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                if _traced_uses(node.value, traced):
                    for t in node.targets:
                        traced.update(_assigned_names(t))
            elif isinstance(node, ast.AugAssign):
                if _traced_uses(node.value, traced):
                    traced.update(_assigned_names(node.target))
        return traced

    def _check_jit_function(self, fn) -> None:
        traced = self._traced_names(fn)
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                self._jit_call(node, traced)
            elif isinstance(node, (ast.If, ast.While)):
                uses = _traced_uses(node.test, traced)
                if uses:
                    self.report(
                        node, "tracer-branch",
                        f"`{fn.name}` is jit-traced but branches on "
                        f"`{uses[0].id}` — a traced value. Mark it static "
                        "(static_argnames / keyword-only flag) or use "
                        "lax.cond / jnp.where")
            elif isinstance(node, ast.For):
                uses = _traced_uses(node.iter, traced)
                if uses:
                    self.report(
                        node, "tracer-branch",
                        f"`{fn.name}` is jit-traced but iterates over a "
                        f"range/sequence derived from `{uses[0].id}` — "
                        "the loop unrolls per traced value; use "
                        "lax.fori_loop / lax.scan")
            elif isinstance(node, ast.JoinedStr):
                for fv in (v for v in node.values
                           if isinstance(v, ast.FormattedValue)):
                    uses = _traced_uses(fv.value, traced)
                    if uses:
                        self.report(
                            node, "fstring-sync",
                            f"f-string formats traced value `{uses[0].id}` "
                            "inside a jit function — this embeds a tracer "
                            "repr (or forces a sync); use jax.debug.print")
                        break

    def _jit_call(self, node: ast.Call, traced: Set[str]) -> None:
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _SYNC_METHODS:
            self.report(node, "jit-host-sync",
                        f".{node.func.attr}() inside a jit-traced function "
                        "forces a host sync (or fails on a tracer)")
            return
        d = _dotted(node.func)
        if d in ("jax.device_get", "device_get"):
            self.report(node, "jit-host-sync",
                        "jax.device_get inside a jit-traced function")
            return
        if d in ("float", "int", "bool") and len(node.args) == 1:
            a = node.args[0]
            bare = isinstance(a, ast.Name) and a.id in traced
            sub = isinstance(a, ast.Subscript) and isinstance(
                a.value, ast.Name) and a.value.id in traced
            if bare or sub:
                self.report(
                    node, "jit-host-sync",
                    f"{d}() on a traced value inside a jit function — "
                    "ConcretizationTypeError at trace time or a silent "
                    "host sync; keep it an array (astype) or pass it static")
            return
        if d and (d.startswith("np.") or d.startswith("numpy.")):
            hit = None
            for a in list(node.args) + [k.value for k in node.keywords]:
                uses = _traced_uses(a, traced)
                if uses:
                    hit = uses[0].id
                    break
            if hit is not None:
                self.report(
                    node, "jit-host-sync",
                    f"{d}(...) on traced value `{hit}` inside a jit "
                    "function — numpy executes on the host; use jnp")

    # ---------------------------------------------------------- host bodies --
    def _check_host_function(self, fn, index: _ModuleIndex) -> None:
        compiled: Set[str] = set()
        device: Set[str] = set()
        host: Set[str] = set()
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign) or not isinstance(
                    node.value, ast.Call):
                continue
            call = node.value
            names = []
            for t in node.targets:
                names.extend(_assigned_names(t))
            d = _dotted(call.func)
            if _is_jit_ref(call.func) or (
                    d is not None and d.split(".")[-1].endswith("_fn")):
                compiled.update(names)
            elif isinstance(call.func, ast.Call) \
                    or (isinstance(call.func, ast.Name)
                        and call.func.id in compiled):
                device.update(names)
            elif d is not None and (d.startswith("np.")
                                    or d.startswith("numpy.")):
                host.update(names)
        # second pass: calls of now-known compiled names feeding assignments
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Call):
                call = node.value
                if isinstance(call.func, ast.Name) \
                        and call.func.id in compiled:
                    for t in node.targets:
                        device.update(
                            n for n in _assigned_names(t) if n not in host)
        device -= host

        for loop in ast.walk(fn):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            if not _contains_call_to(loop, compiled):
                continue
            self._check_hot_loop(loop, fn, device)

    def _check_hot_loop(self, loop, fn, device: Set[str]) -> None:
        for node in ast.walk(loop):
            if isinstance(node, ast.Call):
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _SYNC_METHODS:
                    self.report(
                        node, "hot-host-sync",
                        f".{node.func.attr}() inside `{fn.name}`'s hot loop "
                        "— per-step host sync serializes async dispatch; "
                        "sync once after the loop")
                    continue
                d = _dotted(node.func)
                if d in ("jax.block_until_ready", "jax.device_get"):
                    self.report(
                        node, "hot-host-sync",
                        f"{d} inside `{fn.name}`'s hot loop — per-step "
                        "host sync; sync once after the loop")
                    continue
                if d in ("float", "int", "np.asarray", "np.array",
                         "numpy.asarray", "numpy.array") and node.args:
                    a = node.args[0]
                    nm = None
                    if isinstance(a, ast.Name):
                        nm = a.id
                    elif isinstance(a, ast.Subscript) and isinstance(
                            a.value, ast.Name):
                        nm = a.value.id
                    if nm in device:
                        self.report(
                            node, "hot-host-sync",
                            f"{d}({nm}...) inside `{fn.name}`'s hot loop "
                            "pulls a compiled-step result to the host every "
                            "iteration — batch it or sync after the loop")
            elif isinstance(node, ast.JoinedStr):
                for fv in (v for v in node.values
                           if isinstance(v, ast.FormattedValue)):
                    names = _expr_names(fv.value) & device
                    if names:
                        self.report(
                            node, "fstring-sync",
                            f"f-string formats device value "
                            f"`{sorted(names)[0]}` inside `{fn.name}`'s hot "
                            "loop — formatting syncs every iteration")
                        break

    # ------------------------------------------------------------ key reuse --
    def _check_key_reuse(self, fn) -> None:
        consumed: Dict[str, int] = {}

        def consumer_key(call: ast.Call) -> Optional[str]:
            d = _dotted(call.func)
            if not d:
                return None
            parts = d.split(".")
            if parts[-1] not in _KEY_CONSUMERS:
                return None
            if not ("random" in parts or parts[0] in ("jr", "jrandom")):
                # require a jax.random-ish namespace (or the common aliases)
                # so e.g. str.split never matches
                if len(parts) > 1:
                    return None
                return None
            if call.args and isinstance(call.args[0], ast.Name):
                return call.args[0].id
            return None

        def scan(stmts, in_loop: bool, loop_assigned: Set[str]) -> None:
            for stmt in stmts:
                for node in ast.walk(stmt):
                    if isinstance(node, (ast.For, ast.While)) \
                            and node is not stmt:
                        continue
                if isinstance(stmt, (ast.For, ast.While)):
                    assigned_in = set()
                    for n in ast.walk(stmt):
                        if isinstance(n, ast.Assign):
                            for t in n.targets:
                                assigned_in.update(_assigned_names(t))
                        elif isinstance(n, ast.AugAssign):
                            assigned_in.update(_assigned_names(n.target))
                    if isinstance(stmt, ast.For):
                        assigned_in.update(_assigned_names(stmt.target))
                    body = stmt.body + getattr(stmt, "orelse", [])
                    scan(body, True, assigned_in)
                    continue
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue            # nested defs have their own pass
                for node in ast.walk(stmt):
                    if isinstance(node, ast.Call):
                        key = consumer_key(node)
                        if key is None:
                            continue
                        if key in consumed:
                            self.report(
                                node, "prng-key-reuse",
                                f"PRNG key `{key}` already consumed at line "
                                f"{consumed[key]} — draws correlate; "
                                "split/fold_in first")
                        elif in_loop and key not in loop_assigned:
                            self.report(
                                node, "prng-key-reuse",
                                f"PRNG key `{key}` consumed inside a loop "
                                "without being rebound — every iteration "
                                "draws with the same key")
                        else:
                            consumed[key] = node.lineno
                for node in ast.walk(stmt):
                    if isinstance(node, ast.Assign):
                        for t in node.targets:
                            for nm in _assigned_names(t):
                                consumed.pop(nm, None)

        scan(fn.body, False, set())

    # --------------------------------------------------- nonhashable-static --
    def _check_static_call_sites(self, tree: ast.Module,
                                 index: _ModuleIndex) -> None:
        if not index.static_params:
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call) or not isinstance(
                    node.func, ast.Name):
                continue
            statics = index.static_params.get(node.func.id)
            if not statics:
                continue
            for kw in node.keywords:
                if kw.arg in statics and isinstance(
                        kw.value, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                                   ast.DictComp, ast.SetComp)):
                    self.report(
                        node, "nonhashable-static",
                        f"static arg `{kw.arg}` of `{node.func.id}` gets an "
                        "unhashable literal — jit static args must be "
                        "hashable (use a tuple / frozen dataclass)")

    # --------------------------------------------------------------- pallas --
    def _grid_exprs(self, site: ast.Call) -> List[ast.AST]:
        out: List[ast.AST] = []

        def from_value(v: ast.AST) -> None:
            if isinstance(v, (ast.Tuple, ast.List)):
                out.extend(v.elts)
            else:
                out.append(v)

        for kw in site.keywords:
            if kw.arg == "grid":
                from_value(kw.value)
            elif kw.arg == "grid_spec" and isinstance(kw.value, ast.Call):
                for inner in kw.value.keywords:
                    if inner.arg == "grid":
                        from_value(inner.value)
        return out

    def _check_pallas_site(self, site: ast.Call, index: _ModuleIndex) -> None:
        grid = self._grid_exprs(site)
        uses_cdiv = False
        for e in grid:
            for n in ast.walk(e):
                if isinstance(n, ast.Call):
                    d = _dotted(n.func)
                    if d and d.split(".")[-1] in ("cdiv", "ceil_div"):
                        uses_cdiv = True
                if isinstance(n, ast.BinOp) and isinstance(
                        n.op, ast.FloorDiv):
                    # -(-a // b) is the ceil-div idiom, not a dropped tail
                    if isinstance(n.left, ast.UnaryOp) and isinstance(
                            n.left.op, ast.USub):
                        uses_cdiv = True
                        continue
                    self.report(
                        n, "pallas-grid-floordiv",
                        "grid dimension uses plain // — a non-multiple "
                        "axis silently skips its tail block; use pl.cdiv "
                        "and mask the partial block")
        if not uses_cdiv:
            return
        kern = site.args[0] if site.args else None
        kname = None
        if isinstance(kern, ast.Name):
            kname = kern.id
        elif isinstance(kern, ast.Call) and kern.args and isinstance(
                kern.args[0], ast.Name):
            kname = kern.args[0].id
        fn = index.kernel_functions.get(kname) if kname else None
        if fn is None:
            return
        if not self._kernel_has_masking(fn):
            self.report(
                site, "pallas-partial-mask",
                f"grid uses cdiv (partial tail block) but kernel "
                f"`{kname}` shows no masking (pl.when / jnp.where / "
                "mask= / iota bound check)")

    def _kernel_has_masking(self, fn) -> bool:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                d = _dotted(node.func)
                tail = d.split(".")[-1] if d else ""
                if tail in ("when", "where", "broadcasted_iota", "iota",
                            "program_id", "select"):
                    return True
                if any(kw.arg == "mask" for kw in node.keywords):
                    return True
        return False

    def _check_kernel(self, fn) -> None:
        """pallas-accum-dtype: dots must accumulate in fp32."""
        f32: Set[str] = set()

        def is_f32(expr: ast.AST) -> bool:
            for n in ast.walk(expr):
                if isinstance(n, ast.Call):
                    if isinstance(n.func, ast.Attribute) \
                            and n.func.attr == "astype":
                        for a in list(n.args) + [k.value for k in n.keywords]:
                            d = _dotted(a)
                            if d in ("jnp.float32", "np.float32",
                                     "jax.numpy.float32") or (
                                    isinstance(a, ast.Constant)
                                    and a.value == "float32"):
                                return True
                if isinstance(n, ast.Name) and n.id in f32:
                    return True
                d = _dotted(n)
                if d in ("jnp.float32", "np.float32"):
                    return True
            return False

        # forward pass: names assigned from visibly-fp32 expressions
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and is_f32(node.value):
                for t in node.targets:
                    f32.update(_assigned_names(t))

        for node in ast.walk(fn):
            dot = None
            operands: List[ast.AST] = []
            if isinstance(node, ast.Call):
                d = _dotted(node.func)
                tail = d.split(".")[-1] if d else ""
                if tail in ("dot", "dot_general", "matmul"):
                    dot = node
                    operands = list(node.args[:2])
                    if any(kw.arg == "preferred_element_type"
                           for kw in node.keywords):
                        continue
            elif isinstance(node, ast.BinOp) and isinstance(
                    node.op, ast.MatMult):
                dot = node
                operands = [node.left, node.right]
            if dot is None:
                continue
            if any(is_f32(op) for op in operands):
                continue
            self.report(
                dot, "pallas-accum-dtype",
                f"dot in kernel `{fn.name}` has neither "
                "preferred_element_type=jnp.float32 nor a visibly fp32 "
                "operand — bf16 inputs would accumulate in bf16")


# ------------------------------------------------------------------ drivers --

def lint_source(source: str, path: str = "<string>") -> List[Finding]:
    """Lint one module's source text; returns findings (possibly empty).

    Deduped: nested hot loops (or a function reached via two contexts) can
    visit the same node twice — one finding per (line, col, rule)."""
    seen: Set[Tuple[int, int, str]] = set()
    out: List[Finding] = []
    for f in _Linter(path, source).run():
        key = (f.line, f.col, f.rule)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


def iter_py_files(paths: Sequence[str]) -> Iterable[Path]:
    for p in paths:
        pth = Path(p)
        if pth.is_dir():
            yield from sorted(pth.rglob("*.py"))
        elif pth.suffix == ".py":
            yield pth


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(lint_source(f.read_text(), str(f)))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="jaxlint",
        description="JAX/Pallas-aware static analysis (see module docstring "
                    "for the rule catalog)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)
    if args.list_rules:
        width = max(len(r) for r in RULES)
        for rule, desc in RULES.items():
            print(f"{rule:<{width}}  {desc}")
        return 0
    findings = lint_paths(args.paths)
    for f in findings:
        print(f)
    n_files = len(list(iter_py_files(args.paths)))
    if findings:
        print(f"jaxlint: {len(findings)} finding(s) in {n_files} file(s)")
        return 1
    print(f"jaxlint: clean ({n_files} files)")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
