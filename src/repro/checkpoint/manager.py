"""Checkpoint manager: sharded .npz + JSON manifest, keep-N GC, async save,
elastic mesh-to-mesh restore.

Fault-tolerance contract (DESIGN.md §7):
  * atomic commit — writes go to ``<dir>/tmp.<step>`` and are renamed to
    ``step_<n>`` only when complete, so a crash mid-save never corrupts the tree;
  * restart — ``latest_step``/``restore`` resume from the newest complete
    checkpoint, including the data-pipeline step;
  * elastic — arrays are saved as full (unsharded) values with their
    PartitionSpecs in the manifest; restore re-shards onto *any* current mesh
    (scale up/down = restart with a different mesh);
  * async — ``save_async`` snapshots to host memory synchronously (cheap) and
    writes in a background thread off the step critical path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> PyTree:
    tree: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save ---
    def save(self, step: int, state: PyTree,
             extra: Optional[Dict] = None) -> Path:
        tmp = self.dir / f"tmp.{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(state)
        arrays = {}
        meta = {"step": step, "extra": extra or {}, "leaves": {}}
        for name, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            key = name.replace("/", "__")
            dtype = str(arr.dtype)
            if arr.dtype.kind == "V" or dtype == "bfloat16":
                # numpy can't round-trip ml_dtypes (bf16 etc.) through npz:
                # store the raw bits, record the logical dtype
                arr = arr.view(np.uint16) if arr.dtype.itemsize == 2 \
                    else arr.view(np.uint8)
                dtype = "bfloat16" if dtype in ("bfloat16", "|V2") else dtype
            arrays[key] = arr
            meta["leaves"][name] = {"dtype": dtype,
                                    "shape": list(arr.shape)}
        np.savez(tmp / "arrays.npz", **arrays)
        (tmp / "manifest.json").write_text(json.dumps(meta))
        final = self.dir / f"step_{step:010d}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def save_async(self, step: int, state: PyTree,
                   extra: Optional[Dict] = None) -> None:
        """Snapshot synchronously (device_get), write in the background."""
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def _write():
            self.save(step, host_state, extra)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore ---
    def latest_step(self) -> Optional[int]:
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*"))
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings: PyTree = None
                ) -> Dict[str, Any]:
        """-> {"step", "state", "extra"}; re-shards to ``shardings`` if given
        (elastic restore onto the current mesh)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step:010d}"
        meta = json.loads((path / "manifest.json").read_text())
        import ml_dtypes
        with np.load(path / "arrays.npz") as z:
            flat = {}
            for name, info in meta["leaves"].items():
                arr = z[name.replace("/", "__")]
                if info["dtype"] == "bfloat16" and arr.dtype != np.uint16:
                    pass
                elif info["dtype"] == "bfloat16":
                    arr = arr.view(ml_dtypes.bfloat16)
                flat[name] = arr
        state = _unflatten(flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return {"step": meta["step"], "state": state, "extra": meta["extra"]}

    # -------------------------------------------------------------------- gc ---
    def _gc(self) -> None:
        steps = sorted(self.dir.glob("step_*"))
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)
