"""Gradient-compression collectives + hierarchical pod reduction.

For multi-pod DP the cross-pod all-reduce rides DCN (~6.25 GB/s/chip vs
50 GB/s ICI) — at mistral-123B scale the fp32 gradient all-reduce would cost
123e9*4*2/512/6.25e9 ≈ 300 ms/step of pure DCN time. Int8 compression with
fp32 error feedback (residual accumulation makes the quantization error a
*delayed* rather than lost signal — convergence-neutral in practice) cuts the
wire bytes 4x. Used under ``shard_map`` (explicit-axis code), composable with
the pjit step via ``jax.shard_map`` on the grad pytree.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str,
                    error: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """int8 all-reduce with fp32 error feedback (inside shard_map).

    -> (mean-reduced fp32 value, new error residual to carry to next step).
    """
    x32 = x.astype(jnp.float32)
    if error is not None:
        x32 = x32 + error
    q, scale = quantize_int8(x32)
    new_error = x32 - dequantize_int8(q, scale)
    # sum int32 accumulators and the per-shard scales
    total = jax.lax.psum(q.astype(jnp.int32).astype(jnp.float32) * scale,
                         axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total / n, new_error


def hierarchical_psum(x: jax.Array, inner_axis: str, outer_axis: str
                      ) -> jax.Array:
    """Pod-hierarchical all-reduce: reduce-scatter inside the pod (ICI),
    all-reduce the 1/N shard across pods (DCN), all-gather inside the pod.
    Wire-optimal for DCN: each chip moves only its shard across pods."""
    shard = jax.lax.psum_scatter(x, inner_axis, scatter_dimension=0,
                                 tiled=True)
    shard = jax.lax.psum(shard, outer_axis)
    return jax.lax.all_gather(shard, inner_axis, axis=0, tiled=True)
