"""Logical-axis sharding rules (MaxText-style) + param/batch/cache PartitionSpecs.

Design decisions (see DESIGN.md §4):

* **Feature-dim tensor parallelism.** Query-head counts in the assigned grid (24, 40,
  12...) are not divisible by the 16-way model axis, and JAX rejects uneven input
  shardings. All projection weights are therefore sharded on their *fused feature*
  dimensions (q_dim, kv_dim, d_ff, ssm inner), which are multiples of 16 for every
  arch; GSPMD propagates (and pads) the derived head-dim shardings of intermediate
  activations on its own.

* **Sequence parallelism.** The residual stream between blocks is sharded
  [batch->data, seq->model]. Megatron-SP falls out of GSPMD propagation: all-gather
  into the TP GEMMs, reduce-scatter back — and live activations per device drop 16x,
  which is what lets 88-layer train_4k cells fit 16 GB HBM.

* **Decode KV caches are sharded on the cache-length axis** (S/16 per device): the
  only collectives decode attention needs are then tiny [B,H,1] softmax-stat
  all-reduces and one [B,H,D] output all-reduce, while cache bytes scale 1/256 over
  the pod. (Head-count sharding is illegal for kv=8<16; head_dim sharding would
  all-reduce full score tensors.)

Rules are looked up by *leaf path name* of the parameter pytree — parameter naming in
``repro.models`` is the contract.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

if hasattr(jax, "shard_map"):            # jax >= 0.6
    shard_map = jax.shard_map
else:                                    # jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map  # noqa: F401

Rules = Dict[str, Optional[object]]

_state = threading.local()


# ------------------------------------------------------------------------ rules ---

def make_rules(multi_pod: bool = False, *, seq_parallel: bool = True,
               fsdp: bool = True, expert_parallel: bool = True,
               overrides: Sequence[Tuple[str, Optional[str]]] = ()) -> Rules:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    rules: Rules = {
        # ---- activations ----
        "batch": batch_axes,
        "seq": "model" if seq_parallel else None,     # sequence-parallel residual
        "cache_seq": "model",                         # decode KV cache length
        "embed": None,
        # attention intermediates: q-head dim sharded on model (uneven counts are
        # padded by GSPMD — legal for intermediates, not for jit inputs)
        "q_heads": "model",
        "kv": None,
        "vocab": "model",                             # logits vocab axis
        # ---- parameters ----
        # fsdp: weight-matrix dim sharded over the data axis (ZeRO-3-style weight
        # streaming; params are bf16 so the per-layer all-gather is halved).
        "fsdp": None if not fsdp else "data",
        "tensor": "model",                            # Megatron TP feature dims
        # experts shard over the *model* axis (E: 128/64/16 all divide 16); the
        # per-expert FF dim stays unsharded. GSPMD then moves capacity slots
        # [B->data, E, C, D] to [B->data, E->model, C, D] with an all-to-all over
        # model — classic expert parallelism expressed in pjit. (E over the data
        # axis would fight the batch sharding and re-lay out every MoE layer.)
        "experts": "model" if expert_parallel else None,
        "expert_mlp": None if expert_parallel else "model",
        "opt_flat": ("data", "model"),                # ZeRO-1 optimizer states
        "none": None,
    }
    for name, axis in overrides:
        rules[name] = axis
    return rules


def activate(mesh: Mesh, rules: Rules):
    """Context manager: make (mesh, rules) current for spec()/constrain()."""
    @contextlib.contextmanager
    def _ctx():
        prev = getattr(_state, "ctx", None)
        _state.ctx = (mesh, rules)
        # jax >= 0.6 also wants the mesh ambient for sharding-in-types;
        # constrain() itself builds explicit NamedShardings, so older
        # versions need no global state
        set_mesh = getattr(jax, "set_mesh", contextlib.nullcontext)
        try:
            with set_mesh(mesh):
                yield
        finally:
            _state.ctx = prev
    return _ctx()


def current() -> Optional[Tuple[Mesh, Rules]]:
    return getattr(_state, "ctx", None)


def spec(*logical: Optional[str]) -> P:
    ctx = current()
    if ctx is None:
        return P(*([None] * len(logical)))
    _, rules = ctx
    return P(*[rules.get(l) if l else None for l in logical])


def sharding(*logical: Optional[str]) -> Optional[NamedSharding]:
    ctx = current()
    if ctx is None:
        return None
    mesh, _ = ctx
    return NamedSharding(mesh, spec(*logical))


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint if a mesh is active, else identity."""
    s = sharding(*logical)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


# ----------------------------------------------------------------- param specs ----

# leaf name -> logical axes of the *trailing* dims (leading scan axis padded None).
# Matrices are (fsdp x tensor) sharded: column-parallel weights put their output
# feature dim on "tensor", row-parallel their input dim; the other big dim streams
# over "fsdp". Every "tensor"/"fsdp" dim is a multiple of 16 for all archs.
_PARAM_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "embedding": ("tensor", "fsdp"),     # [V, D] vocab-sharded
    "pos_embedding": (None, None),
    "head": ("fsdp", "tensor"),          # [D, V]
    "wqkv": ("fsdp", "tensor"),
    "wq": ("fsdp", "tensor"),
    "wk": ("fsdp", "tensor"),
    "wv": ("fsdp", "tensor"),
    "wo": ("tensor", "fsdp"),
    "bqkv": ("tensor",),
    "bq": ("tensor",),
    "bk": ("tensor",),
    "bv": ("tensor",),
    "bo": (None,),
    "w1": ("fsdp", "tensor"),
    "w3": ("fsdp", "tensor"),
    "w2": ("tensor", "fsdp"),
    "b1": ("tensor",),
    "b3": ("tensor",),
    "b2": (None,),
    "router": ("fsdp", None),
    "in_proj": ("fsdp", "tensor"),
    "out_proj": ("tensor", "fsdp"),
    "conv": (None, "tensor"),
    "A_log": (None,),
    "D": (None,),
    "dt_bias": (None,),
    "norm_scale": (None,),
    "scale": (None,),
    "bias": (None,),
    "dense": ("fsdp", None),
}

# under an "experts" parent the matrices carry a leading expert dim:
# E -> model (expert parallelism), D -> data (FSDP weight streaming). The
# per-expert FF dim stays whole so each expert's GEMM runs on its owner shard.
_EXPERT_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "w1": ("experts", "fsdp", None),
    "w3": ("experts", "fsdp", None),
    "w2": ("experts", None, "fsdp"),
}


def _leaf_spec(path: Tuple[str, ...], leaf) -> P:
    name = path[-1]
    in_experts = "experts" in path[:-1]
    table = _EXPERT_RULES if (in_experts and name in _EXPERT_RULES) else _PARAM_RULES
    if name not in table:
        raise KeyError(f"no sharding rule for parameter {'/'.join(path)}")
    logical = table[name]
    pad = leaf.ndim - len(logical)
    assert pad >= 0, (path, leaf.shape, logical)
    return spec(*([None] * pad + list(logical)))


def _path_names(key_path) -> Tuple[str, ...]:
    names = []
    for k in key_path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_pspecs(params) -> object:
    """PartitionSpec pytree mirroring a parameter pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: _leaf_spec(_path_names(kp), leaf), params)


def param_shardings(params, mesh: Mesh) -> object:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), param_pspecs(params),
                        is_leaf=lambda s: isinstance(s, P))


# ----------------------------------------------------------- batch / cache specs --

def batch_pspecs(batch: Dict[str, jax.Array]) -> Dict[str, P]:
    """Input batches: leading batch dim -> data(+pod); everything else replicated."""
    out = {}
    for name, v in batch.items():
        if name == "mrope_positions":        # [3, B, S]
            out[name] = spec(None, "batch", None)
        elif v.ndim >= 1:
            out[name] = spec(*(["batch"] + [None] * (v.ndim - 1)))
        else:
            out[name] = P()
    return out


def opt_state_pspecs(state, params_specs, zero1: bool) -> object:
    """Optimizer-state specs.

    zero1: flat [Z, padded] leaves fully sharded over (data, model) — ZeRO-1.
    else : m/v mirror the parameter specs (data-replicated, the paper-faithful
           baseline whose 4x-model-size LAMB traffic Takeaway 8 measures).
    """
    ctx = current()
    rules = dict(ctx[1]) if ctx else {}
    # ZeRO sharding stays within one pod (DCN all-gathers per step would dominate)
    flat_axes = rules.get("opt_flat", ("data", "model"))
    expert_axis = rules.get("experts")

    def flat_spec(key_path, leaf):
        names = _path_names(key_path)
        if "experts" in names and leaf.ndim == 3:
            # [Z, E, flat]: expert dim keeps its model sharding; flat over data
            return P(None, expert_axis, "data")
        if leaf.ndim == 2 and "experts" in names:
            return P(expert_axis, "data")
        return P(*([None] * (leaf.ndim - 1) + [flat_axes]))

    out = {}
    for k, v in state.items():
        if k == "step":
            out[k] = P()
        elif zero1:
            out[k] = jax.tree_util.tree_map_with_path(flat_spec, v)
        else:
            out[k] = params_specs
    return out


def _sanitize(spec: P, shape: Tuple[int, ...], axis_sizes) -> P:
    out = []
    for i, axes in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            out.append(None)
            continue
        axes_t = axes if isinstance(axes, tuple) else (axes,)
        kept = []
        size = 1
        for a in axes_t:
            s = axis_sizes[a]
            if shape[i] % (size * s) == 0:
                kept.append(a)
                size *= s
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def sanitize_spec(spec: P, shape: Tuple[int, ...]) -> P:
    """Drop mesh axes from dims they don't divide (jit inputs must divide
    evenly — e.g. the batch axis on global_batch=1 long-context cells)."""
    ctx = current()
    if ctx is None:
        return spec
    mesh, _ = ctx
    return _sanitize(spec, shape, mesh.shape)


def sanitize_tree(specs, structs):
    return jax.tree.map(
        lambda s, x: sanitize_spec(s, x.shape), specs, structs,
        is_leaf=lambda s: isinstance(s, P))


def flat_grad_pspec(key_path, leaf) -> P:
    """Spec for a flat-layout (ZeRO-2 style) gradient-accumulation leaf."""
    ctx = current()
    rules = dict(ctx[1]) if ctx else {}
    names = _path_names(key_path)
    if "experts" in names and leaf.ndim == 3:
        return P(None, rules.get("experts"), "data")
    flat_axes = rules.get("opt_flat", ("data", "model"))
    return P(*([None] * (leaf.ndim - 1) + [flat_axes]))


def constrain_flat(tree) -> object:
    """Constrain a flat-layout grad tree to its ZeRO sharding."""
    if current() is None:
        return tree
    mesh, _ = current()
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, flat_grad_pspec(kp, leaf))), tree)


# -------------------------------------------------------- serving TP specs ----

# Megatron tensor-parallel layout for the *serving* engine's shard_map path
# (replicated activations, head-sharded attention, column/row-parallel MLP).
# Unlike the training rules above these name the mesh axis directly — the
# serving mesh is a fixed 1-D ("model",) mesh, there is no logical-rule
# indirection to thread through shard_map's in_specs. Biases of row-parallel
# projections (bo, b2) stay replicated: they are added once, AFTER the psum.
_SERVING_TP_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "wq": (None, "model"),      # column-parallel: each shard owns Hq/tp heads
    "wk": (None, "model"),      # (contiguous head blocks — q/kv dims are
    "wv": (None, "model"),      #  head-major, so block i == heads of shard i)
    "bq": ("model",),
    "bk": ("model",),
    "bv": ("model",),
    "wo": ("model", None),      # row-parallel: partial sums -> psum
    "w1": (None, "model"),      # column-parallel d_ff
    "w3": (None, "model"),
    "b1": ("model",),
    "b3": ("model",),
    "w2": ("model", None),      # row-parallel: partial sums -> psum
}

# under an "experts" parent the matrices carry a leading [E, ...] expert dim:
# E -> model (expert parallelism), the per-expert GEMM dims whole — each
# shard owns E/tp complete experts and the combine meets in one psum.
# ("shared" experts are a plain dense MLP and take the column/row rules.)
_SERVING_EXPERT_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "w1": ("model", None, None),
    "w3": ("model", None, None),
    "w2": ("model", None, None),
}


def serving_param_pspecs(params) -> object:
    """PartitionSpec pytree for the TP serving engine (shard_map in_specs).

    Attention/MLP projections follow ``_SERVING_TP_RULES``; routed-expert
    weights shard E-major per ``_SERVING_EXPERT_RULES``; every other leaf —
    embedding, lm head, norms, router, mamba mixers, row-parallel biases —
    is replicated, so the logits (and therefore the sampler's draws) are
    computed identically on every shard and the emitted token vector needs
    no collective at all. Fused ``wqkv``/``bqkv`` leaves are rejected: a
    contiguous slice of the fused feature dim would mix q and kv columns —
    the engine splits them into wq/wk/wv before sharding
    (``serving.engine._split_fused_qkv``).
    """
    def leaf_spec(key_path, leaf):
        names = _path_names(key_path)
        name = names[-1]
        if name in ("wqkv", "bqkv"):
            raise ValueError(
                "fused qkv cannot be head-sharded; split into wq/wk/wv first "
                f"({'/'.join(names)})")
        if "experts" in names[:-1] and name in _SERVING_EXPERT_RULES:
            logical = _SERVING_EXPERT_RULES[name]
        else:
            logical = _SERVING_TP_RULES.get(name)
        if logical is None:
            return P(*([None] * leaf.ndim))
        pad = leaf.ndim - len(logical)
        assert pad >= 0, (key_path, leaf.shape, logical)
        return P(*([None] * pad + list(logical)))
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


# leaf names of the serving decode-state tree — the one definition shared by
# the pspec builder here and the engine's CoW page copy / KV-head-replication
# transforms (a new paged layer kind must extend these, nowhere else)
PAGED_STATE_LEAVES = ("k", "v")         # per-page KV pools [P, page, Hkv, Dh]
SLOT_STATE_LEAVES = ("conv", "state")   # per-slot mamba state


def paged_pool_pspecs(pools) -> object:
    """Shard the engine's per-layer decode state for TP serving.

    Attention page pools (``PAGED_STATE_LEAVES``, [P, page, Hkv, Dh];
    scanned stacks carry a leading period axis) shard the KV-head axis —
    always ndim-2 — on "model". Page ids stay global: each shard holds the
    same pages, 1/tp of every page's heads, so one host allocator/page
    table drives all shards. Mamba slot-state leaves (``SLOT_STATE_LEAVES``)
    stay replicated: the mixer's weights are replicated, every shard
    advances the identical recurrence, and the state is too small to be
    worth the collectives sharding it would cost."""
    def leaf_spec(key_path, leaf):
        name = _path_names(key_path)[-1]
        if name in PAGED_STATE_LEAVES:
            spec = [None] * leaf.ndim
            spec[-2] = "model"
            return P(*spec)
        if name in SLOT_STATE_LEAVES:
            return P(*([None] * leaf.ndim))
        raise KeyError(f"no serving-state sharding rule for "
                       f"{'/'.join(_path_names(key_path))}")
    return jax.tree_util.tree_map_with_path(leaf_spec, pools)


def shard_map_tp(f, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, across jax versions.

    The TP serving steps return psum-replicated values (token ids) under a
    ``P()``/``P(None)`` out_spec; the replication checker cannot always prove
    that through the sampler's PRNG ops, and its keyword changed name
    (check_rep -> check_vma) across the versions this repo supports."""
    try:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def _cache_leaf_spec(path: Tuple[str, ...], leaf) -> P:
    name = path[-1]
    if name in ("k", "v", "cross_k", "cross_v"):
        # [(periods,)] B, S, Hkv, Dh — shard the cache-length axis on model
        logical = ("batch", "cache_seq", None, None)
    elif name == "conv":
        # [(periods,)] B, W-1, C
        logical = ("batch", None, "conv_ch")
    elif name == "state":
        # [(periods,)] B, H, N, P
        logical = ("batch", None, None, None)
    else:
        raise KeyError(f"no cache rule for {'/'.join(path)}")
    pad = leaf.ndim - len(logical)
    return spec(*([None] * pad + list(logical)))


def cache_pspecs(caches) -> object:
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: _cache_leaf_spec(_path_names(kp), leaf), caches)
