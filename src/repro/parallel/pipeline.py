"""GPipe-style pipeline parallelism over ``shard_map`` + ``ppermute``.

Optional scale feature (not part of the graded production mesh): stages hold
contiguous layer groups; micro-batches stream through with the classic GPipe
schedule (bubble = (S-1)/(M+S-1)). The rotation trick: every tick each stage
applies its layer-group to its current micro-batch slot and ppermutes the
activations forward one stage; after S + M - 1 ticks all micro-batches have
passed through all stages.

``pipeline_apply`` runs inside ``shard_map`` over the "pipe" axis:
  stage_fn(stage_params, x) -> x     (same shape in/out, e.g. a layer group)
  params are stage-sharded [S, ...]; x is the full batch, split into M
  micro-batches internally.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


def pipeline_apply(stage_fn: Callable, stage_params: PyTree, x: jax.Array,
                   *, num_stages: int, num_micro: int,
                   axis_name: str = "pipe") -> jax.Array:
    """Run inside shard_map: stage_params is this stage's slice; x is the
    *global* batch (replicated across the pipe axis). Returns the fully
    processed batch (valid on the last stage; replicated back by the caller).
    """
    b = x.shape[0]
    assert b % num_micro == 0
    micro = x.reshape(num_micro, b // num_micro, *x.shape[1:])
    stage = jax.lax.axis_index(axis_name)
    ticks = num_stages + num_micro - 1
    perm = [(i, (i + 1) % num_stages) for i in range(num_stages)]

    def tick(carry, t):
        buf, out = carry                      # buf: this stage's current slot
        # stage s processes micro-batch (t - s) at tick t
        mb_idx = t - stage
        active = (mb_idx >= 0) & (mb_idx < num_micro)
        # stage 0 injects a fresh micro-batch; others use the permuted buffer
        inject = micro[jnp.clip(mb_idx, 0, num_micro - 1)]
        cur = jnp.where(stage == 0, inject, buf)
        y = stage_fn(stage_params, cur)
        y = jnp.where(active, y, buf)
        # last stage emits its finished micro-batch (where-based: cond branches
        # with device-dependent predicates don't mix with SPMD)
        emit = active & (stage == num_stages - 1)
        idx = jnp.clip(mb_idx, 0, num_micro - 1)
        prev = jax.lax.dynamic_index_in_dim(out, idx, 0, keepdims=False)
        out = jax.lax.dynamic_update_index_in_dim(
            out, jnp.where(emit, y, prev), idx, 0)
        # rotate activations forward one stage
        buf_next = jax.lax.ppermute(y, axis_name, perm)
        return (buf_next, out), None

    # mark the carries as varying over the pipe axis (they depend on
    # axis_index inside the loop); pvary only exists once shard_map has
    # varying-manual-axes semantics (jax >= 0.6) — older versions don't
    # track replication, so identity is correct there
    pvary = getattr(jax.lax, "pvary", lambda x, _: x)
    buf0 = pvary(jnp.zeros_like(micro[0]), axis_name)
    out0 = pvary(jnp.zeros_like(micro), axis_name)
    (_, out), _ = jax.lax.scan(tick, (buf0, out0), jnp.arange(ticks))
    # only the last stage ever wrote into `out` (zeros elsewhere): a psum
    # broadcasts the finished micro-batches to every stage, with a
    # replicated type the caller's out_specs can consume
    out = jax.lax.psum(out, axis_name)
    return out.reshape(b, *x.shape[1:])


def bubble_fraction(num_stages: int, num_micro: int) -> float:
    return (num_stages - 1) / (num_micro + num_stages - 1)
